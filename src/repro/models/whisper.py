"""Whisper — encoder/decoder speech transformer (conv frontend stubbed).

[arXiv:2212.04356]  The convolutional mel-spectrogram frontend is a STUB
per the assignment: ``input_specs()`` supplies precomputed frame
embeddings ``[B, n_audio_ctx, d_model]``; everything after that (both
transformer stacks, cross attention, LayerNorm+GELU as in the paper) is
fully implemented.

Serving: the decoder self-attn KV cache grows per step; encoder output
and per-layer cross-attention K/V are computed once at prefill and reused
every decode step.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _sinusoids(length: int, channels: int) -> jnp.ndarray:
    """Whisper's sinusoidal encoder positions."""
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    t = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def _gelu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def _init_gelu_mlp(rng, d, f, dtype):
    r = jax.random.split(rng, 2)
    return {
        "w1": L.dense_init(r[0], (d, f), dtype=dtype),
        "b1": jnp.zeros((f,), dtype),
        "w2": L.dense_init(r[1], (f, d), dtype=dtype),
        "b2": jnp.zeros((d,), dtype),
    }


def _ln(rng_unused, d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


class WhisperLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init -------------------------------------------------------------
    def _init_enc_block(self, rng) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        r = jax.random.split(rng, 2)
        return {
            "ln1": _ln(None, cfg.d_model, dt),
            "attn": L.init_attention(r[0], cfg, dt),
            "ln2": _ln(None, cfg.d_model, dt),
            "mlp": _init_gelu_mlp(r[1], cfg.d_model, cfg.d_ff, dt),
        }

    def _init_dec_block(self, rng) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        r = jax.random.split(rng, 3)
        return {
            "ln1": _ln(None, cfg.d_model, dt),
            "self_attn": L.init_attention(r[0], cfg, dt),
            "ln_x": _ln(None, cfg.d_model, dt),
            "cross_attn": L.init_attention(r[1], cfg, dt),
            "ln2": _ln(None, cfg.d_model, dt),
            "mlp": _init_gelu_mlp(r[2], cfg.d_model, cfg.d_ff, dt),
        }

    def init(self, rng) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        n_enc = cfg.n_encoder_layers
        r = jax.random.split(rng, 4 + n_enc + cfg.n_layers)
        enc = [self._init_enc_block(r[4 + i]) for i in range(n_enc)]
        dec = [self._init_dec_block(r[4 + n_enc + i]) for i in range(cfg.n_layers)]
        return {
            "embed": L.dense_init(r[0], (cfg.vocab_size, cfg.d_model),
                                  scale=0.02, dtype=dt),
            "dec_pos": L.dense_init(r[1], (cfg.max_positions, cfg.d_model),
                                    scale=0.01, dtype=dt),
            "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
            "enc_ln": _ln(None, cfg.d_model, dt),
            "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
            "dec_ln": _ln(None, cfg.d_model, dt),
        }

    # -- encoder ------------------------------------------------------------
    def encode(self, params: Params, audio_embeds: jnp.ndarray) -> jnp.ndarray:
        """audio_embeds [B, n_audio_ctx, D] (stub frontend output)."""
        cfg = self.cfg
        x = audio_embeds + _sinusoids(
            audio_embeds.shape[1], cfg.d_model).astype(audio_embeds.dtype)

        def block(bp, x):
            h = L.layer_norm(x, bp["ln1"]["w"], bp["ln1"]["b"], cfg.norm_eps)
            out, _ = L.attention(bp["attn"], h, cfg, causal=False, use_rope=False)
            x = x + out
            h = L.layer_norm(x, bp["ln2"]["w"], bp["ln2"]["b"], cfg.norm_eps)
            return x + _gelu_mlp(bp["mlp"], h)

        def body(x, bp):
            fn = jax.checkpoint(block) if cfg.remat == "block" else block
            return fn(bp, x), None

        if cfg.use_scan:
            x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        else:
            n = jax.tree.leaves(params["enc_blocks"])[0].shape[0]
            for i in range(n):
                bp = jax.tree.map(lambda a: a[i], params["enc_blocks"])
                x, _ = body(x, bp)
        return L.layer_norm(x, params["enc_ln"]["w"], params["enc_ln"]["b"],
                            cfg.norm_eps)

    # -- decoder ------------------------------------------------------------
    def _dec_block(self, bp, x, enc_out, positions):
        cfg = self.cfg
        if cfg.sequence_parallel:
            x = L.sp_constrain(x)
        h = L.layer_norm(x, bp["ln1"]["w"], bp["ln1"]["b"], cfg.norm_eps)
        out, kv = L.attention(bp["self_attn"], h, cfg, causal=True,
                              positions=positions, use_rope=False)
        x = x + out
        h = L.layer_norm(x, bp["ln_x"]["w"], bp["ln_x"]["b"], cfg.norm_eps)
        out, xkv = L.attention(bp["cross_attn"], h, cfg, kv_override=(enc_out,))
        x = x + out
        h = L.layer_norm(x, bp["ln2"]["w"], bp["ln2"]["b"], cfg.norm_eps)
        return x + _gelu_mlp(bp["mlp"], h), kv, xkv

    def forward(self, params, tokens, frontend_embeds=None,
                return_features=False):
        """Teacher-forced training: tokens [B,S] + audio stub [B,A,D]."""
        cfg = self.cfg
        enc_out = self.encode(params, frontend_embeds)
        S = tokens.shape[1]
        x = params["embed"][tokens] + params["dec_pos"][:S].astype(_dtype(cfg))
        positions = jnp.arange(S)

        def block(bp, x):
            x, _, _ = self._dec_block(bp, x, enc_out, positions)
            return x

        def body(x, bp):
            fn = jax.checkpoint(block) if cfg.remat == "block" else block
            return fn(bp, x), None

        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        x = L.layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"],
                         cfg.norm_eps)
        if return_features:
            return x, jnp.zeros((), jnp.float32)
        return x @ params["embed"].T, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        from .transformer import lm_loss
        feats, _ = self.forward(
            params, batch["tokens"], batch["frontend_embeds"],
            return_features=True)
        return lm_loss(feats, params["embed"].T, batch["labels"],
                       self.cfg.loss_chunk_size)

    # -- serving --------------------------------------------------------------
    def init_cache(self, batch: int, s_max: int, dtype=None) -> Params:
        cfg = self.cfg
        dt = dtype or _dtype(cfg)
        n, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        A = cfg.n_audio_ctx
        return {
            "k": jnp.zeros((n, batch, kv, s_max, hd), dt),
            "v": jnp.zeros((n, batch, kv, s_max, hd), dt),
            "xk": jnp.zeros((n, batch, kv, A, hd), dt),
            "xv": jnp.zeros((n, batch, kv, A, hd), dt),
            "pos": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, tokens, frontend_embeds=None):
        """Encode audio + teacher-forced pass over the prompt tokens."""
        cfg = self.cfg
        enc_out = self.encode(params, frontend_embeds)
        B, S = tokens.shape
        x = params["embed"][tokens] + params["dec_pos"][:S].astype(_dtype(cfg))
        positions = jnp.arange(S)

        def body(x, bp):
            x, kv, xkv = self._dec_block(bp, x, enc_out, positions)
            return x, (kv["k"], kv["v"], xkv["k"], xkv["v"])

        if cfg.use_scan:
            x, (k, v, xk, xv) = jax.lax.scan(body, x, params["dec_blocks"])
        else:
            n = jax.tree.leaves(params["dec_blocks"])[0].shape[0]
            ks, vs, xks, xvs = [], [], [], []
            for i in range(n):
                bp = jax.tree.map(lambda a: a[i], params["dec_blocks"])
                x, kv, xkv = self._dec_block(bp, x, enc_out, positions)
                ks.append(kv["k"]); vs.append(kv["v"])
                xks.append(xkv["k"]); xvs.append(xkv["v"])
            k, v = jnp.stack(ks), jnp.stack(vs)
            xk, xv = jnp.stack(xks), jnp.stack(xvs)
        x = L.layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"],
                         cfg.norm_eps)
        logits = x[:, -1] @ params["embed"].T
        return logits, {
            "k": k, "v": v, "xk": xk, "xv": xv,
            "pos": jnp.asarray(S, jnp.int32),
        }

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        pos = cache["pos"]
        x = params["embed"][tokens][:, None, :]
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], pos, 1, axis=0).astype(x.dtype)

        def body(x, inp):
            bp, k, v, xk, xv = inp
            h = L.layer_norm(x, bp["ln1"]["w"], bp["ln1"]["b"], cfg.norm_eps)
            out, nc = L.attention_decode(
                bp["self_attn"], h, {"k": k, "v": v}, pos, cfg, use_rope=False)
            x = x + out
            h = L.layer_norm(x, bp["ln_x"]["w"], bp["ln_x"]["b"], cfg.norm_eps)
            out = _cross_decode(bp["cross_attn"], h, xk, xv, cfg)
            x = x + out
            h = L.layer_norm(x, bp["ln2"]["w"], bp["ln2"]["b"], cfg.norm_eps)
            x = x + _gelu_mlp(bp["mlp"], h)
            return x, (nc["k"], nc["v"])

        if cfg.use_scan:
            x, (k, v) = jax.lax.scan(
                body, x,
                (params["dec_blocks"], cache["k"], cache["v"],
                 cache["xk"], cache["xv"]))
        else:
            n = jax.tree.leaves(params["dec_blocks"])[0].shape[0]
            ks, vs = [], []
            for i in range(n):
                inp = jax.tree.map(
                    lambda a: a[i],
                    (params["dec_blocks"], cache["k"], cache["v"],
                     cache["xk"], cache["xv"]))
                x, (ki, vi) = body(x, inp)
                ks.append(ki)
                vs.append(vi)
            k, v = jnp.stack(ks), jnp.stack(vs)
        x = L.layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"],
                         cfg.norm_eps)
        logits = (x @ params["embed"].T)[:, 0]
        return logits, {
            "k": k, "v": v, "xk": cache["xk"], "xv": cache["xv"],
            "pos": pos + 1,
        }


def _cross_decode(p, x, xk, xv, cfg: ModelConfig):
    """Single-query cross attention against precomputed enc K/V."""
    B = x.shape[0]
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    out = L._sdpa(q, xk, xv, causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"]
