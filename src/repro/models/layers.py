"""Shared neural-net building blocks (pure functional JAX).

Parameters are plain nested dicts of jnp arrays; every function is
``f(params, inputs, config) -> outputs`` so the same code paths lower
under jit/pjit with any sharding.  Blocks are written so layer-stacked
parameters (leading ``L`` dim) can be scanned (small HLO — essential for
compiling 40-60-layer models on the CPU dry-run).

Conventions:
* attention weights: ``wq [D, H*hd]``, ``wk/wv [D, KV*hd]``, ``wo [H*hd, D]``
* gated MLP: ``w1 (gate) [D, F]``, ``w3 (up) [D, F]``, ``w2 (down) [F, D]``
* MoE experts carry a leading ``E`` dim; shared experts are a fused MLP.
* KV caches: ``{'k': [B, KV, S_max, hd], 'v': [B, KV, S_max, hd]}``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = [
    "dense_init", "rms_norm", "layer_norm", "make_rope", "apply_rope",
    "attention", "attention_decode", "mlp", "moe_dense", "moe_scatter",
    "moe_layer", "mla_attention", "mla_attention_decode",
    "init_attention", "init_mlp", "init_moe", "init_mla",
]

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# sequence parallelism (SP)
# ---------------------------------------------------------------------------
# With TP, activations between blocks are replicated across the model axis,
# so the remat-saved per-layer stack costs B_loc * S * d * L — the Megatron
# sequence-parallel fix shards the inter-block activation over the model
# axis on the S dim.  The mesh context is configured at trace time by the
# launcher (specs/train drivers); when unset this is a no-op, so model code
# stays mesh-agnostic.

_SP_STATE = {"dp": None, "tp": None, "tp_size": 1}


def set_sequence_parallel(dp_axes, tp_axis, tp_size) -> None:
    _SP_STATE.update(dp=tuple(dp_axes) if dp_axes else None,
                     tp=tp_axis, tp_size=tp_size)


def clear_sequence_parallel() -> None:
    _SP_STATE.update(dp=None, tp=None, tp_size=1)


def sp_constrain(x: jnp.ndarray) -> jnp.ndarray:
    """Constrain [B, S, D] activations to (dp, model, None) sharding."""
    tp = _SP_STATE["tp"]
    if tp is None or x.ndim != 3 or x.shape[1] % max(_SP_STATE["tp_size"], 1):
        return x
    from jax.sharding import PartitionSpec as P

    dp = _SP_STATE["dp"] or ()
    return jax.lax.with_sharding_constraint(x, P(dp, tp, None))


def sp_shard_heads(t: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """Pin [B, H, S, d] tensors to head-sharding over the model axis."""
    tp = _SP_STATE["tp"]
    if tp is None or t.ndim != 4 or n_heads % max(_SP_STATE["tp_size"], 1):
        return t
    from jax.sharding import PartitionSpec as P

    dp = _SP_STATE["dp"] or ()
    return jax.lax.with_sharding_constraint(t, P(dp, tp, None, None))


def sp_head_constrain(head: jnp.ndarray) -> jnp.ndarray:
    """Pin [D, V] unembedding to vocab-sharding over the model axis."""
    tp = _SP_STATE["tp"]
    if tp is None or head.ndim != 2 or head.shape[1] % max(_SP_STATE["tp_size"], 1):
        return head
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(head, P(None, tp))


def sp_gather_kv(k: jnp.ndarray, cfg) -> jnp.ndarray:
    """Force [B, KV, S, hd] K/V into gathered-S, head-sharded layout."""
    tp = _SP_STATE["tp"]
    if tp is None or k.ndim != 4:
        return k
    from jax.sharding import PartitionSpec as P

    dp = _SP_STATE["dp"] or ()
    heads = tp if k.shape[1] % max(_SP_STATE["tp_size"], 1) == 0 else None
    return jax.lax.with_sharding_constraint(k, P(dp, heads, None, None))


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(rng, shape, scale: Optional[float] = None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(rng, shape, dtype=jnp.float32) * scale).astype(dtype)


def _split(rng, n):
    return jax.random.split(rng, n)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Mixed-precision RMSNorm: the variance reduction runs in f32 but the
    (large) normalized product stays in x.dtype — keeps XLA from
    materializing an f32 copy of the activation as a scan residual."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = (jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return x * scale * w.astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def make_rope(positions: jnp.ndarray, dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for ``positions`` [..., S] -> [..., S, dim/2]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [B, H, S, hd]; cos/sin: [S, hd/2] or [B, S, hd/2] (half-split)."""
    if cos.ndim == 2:
        cos = cos[None, None, :, :]
        sin = sin[None, None, :, :]
    else:
        cos = cos[:, None, :, :]
        sin = sin[:, None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (XLA path; the Pallas flash kernel plugs in via kernels/ops)
# ---------------------------------------------------------------------------

def init_attention(rng, cfg: ModelConfig, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    r = _split(rng, 5)
    p = {
        "wq": dense_init(r[0], (d, h * hd), dtype=dtype),
        "wk": dense_init(r[1], (d, kv * hd), dtype=dtype),
        "wv": dense_init(r[2], (d, kv * hd), dtype=dtype),
        "wo": dense_init(r[3], (h * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _qkv(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, kv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, kv, hd).transpose(0, 2, 1, 3)
    return q, k, v


def _sdpa(q, k, v, *, causal: bool, window: int = 0,
          q_positions=None, kv_positions=None, q_chunk: int = 0) -> jnp.ndarray:
    """Grouped scaled-dot-product attention, f32 softmax.

    q: [B, H, Sq, hd]; k/v: [B, KV, Sk, hd] with H % KV == 0.

    ``q_chunk`` > 0 enables blockwise evaluation over query chunks
    (lax.map), bounding the transient [.., q_chunk, Sk] score tensor —
    the XLA-path analogue of flash attention's memory behavior (the
    Pallas kernel in repro.kernels is the TPU fast path).
    """
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qp = q_positions if q_positions is not None else jnp.arange(Sq)
    kp = kv_positions if kv_positions is not None else jnp.arange(k.shape[2])

    def block(q_blk, qp_blk):
        # q_blk: [B, KV, G, c, hd]
        scores = jnp.einsum("bkgqd,bksd->bkgqs", q_blk, k).astype(jnp.float32)
        scores = scores / math.sqrt(hd)
        if causal or window:
            rel = qp_blk[:, None] - kp[None, :]
            mask = rel >= 0 if causal else jnp.ones_like(rel, dtype=bool)
            if window:
                mask = mask & (rel < window)
            scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgqs,bksd->bkgqd", probs, v)

    qg = q.reshape(B, KV, G, Sq, hd)
    if q_chunk and Sq > 2 * q_chunk and Sq % q_chunk == 0:
        n = Sq // q_chunk
        qc = qg.reshape(B, KV, G, n, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
        qpc = qp.reshape(n, q_chunk)
        # checkpoint per chunk: the backward pass re-derives each chunk's
        # [.., q_chunk, Sk] scores instead of stacking all chunks' scores
        # as scan residuals (which would reintroduce the O(S^2) buffer).
        out = jax.lax.map(lambda t: jax.checkpoint(block)(*t), (qc, qpc))
        out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, KV, G, Sq, -1)
    else:
        out = block(qg, qp)
    return out.reshape(B, H, Sq, v.shape[-1])


def attention(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    positions: Optional[jnp.ndarray] = None,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    use_rope: bool = True,
    window: int = 0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence attention.  Returns (out [B,S,D], kv for caching).

    ``kv_override`` switches to cross-attention (whisper decoder): k/v are
    projected from the override source instead of x.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    if kv_override is not None:
        q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        src = kv_override[0]
        Sk = src.shape[1]
        k = (src @ p["wk"]).reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        v = (src @ p["wv"]).reshape(B, Sk, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        out = _sdpa(q, k, v, causal=False, q_chunk=cfg.attn_q_chunk)
    else:
        q, k, v = _qkv(p, x, cfg)
        if use_rope:
            cos, sin = make_rope(positions, cfg.head_dim, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        if cfg.attn_q_chunk and getattr(cfg, "hoist_kv_gather", True):
            # Under SP, k/v inherit the S-sharding of x; the q-chunk map
            # closes over them and XLA places the (S) all-gather INSIDE
            # the loop — one gather per chunk (measured 27x collective
            # amplification, EXPERIMENTS.md §Perf-3).  Re-assert the
            # gathered layout here so the gather is hoisted above the map.
            k = sp_gather_kv(k, cfg)
            v = sp_gather_kv(v, cfg)
        out = _sdpa(q, k, v, causal=causal, window=window,
                    q_positions=positions, kv_positions=positions,
                    q_chunk=cfg.attn_q_chunk)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"], {"k": k, "v": v}


def attention_decode(
    p: Params,
    x: jnp.ndarray,                 # [B, 1, D]
    cache: Dict[str, jnp.ndarray],  # k/v: [B, KV, S_max, hd]
    pos: jnp.ndarray,               # scalar int32: write index
    cfg: ModelConfig,
    *,
    window: int = 0,
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode with KV-cache update."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(p, x, cfg)
    if use_rope:
        cos, sin = make_rope(pos[None], cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, 0, pos, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, 0, pos, 0))
    S_max = k.shape[2]
    kp = jnp.arange(S_max)
    valid = kp <= pos
    if window:
        valid = valid & (kp > pos - window)
    KV, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(B, KV, G, 1, cfg.head_dim)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qh, k).astype(jnp.float32)
    scores = scores / math.sqrt(cfg.head_dim)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, v)
    out = out.reshape(B, cfg.n_heads, 1, cfg.head_dim).transpose(0, 2, 1, 3)
    out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"], {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def init_mlp(rng, d: int, f: int, dtype) -> Params:
    r = _split(rng, 3)
    return {
        "w1": dense_init(r[0], (d, f), dtype=dtype),
        "w3": dense_init(r[1], (d, f), dtype=dtype),
        "w2": dense_init(r[2], (f, d), dtype=dtype),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


def init_moe(rng, cfg: ModelConfig, dtype) -> Params:
    d, fe, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    r = _split(rng, 5)
    p = {
        "router": dense_init(r[0], (d, E), scale=0.02, dtype=jnp.float32),
        "w1": dense_init(r[1], (E, d, fe), dtype=dtype),
        "w3": dense_init(r[2], (E, d, fe), dtype=dtype),
        "w2": dense_init(r[3], (E, fe, d), dtype=dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(r[4], d, fe * cfg.n_shared_experts, dtype)
    return p


def _router_probs(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    """top-k gating.  Returns (expert_idx [.., k], weights [.., k], aux_loss)."""
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    E = cfg.n_experts
    me = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    ce = jnp.mean(
        (jax.nn.one_hot(idx, E).sum(-2) > 0).astype(jnp.float32),
        axis=tuple(range(probs.ndim - 1)),
    )
    aux = E * jnp.sum(me * ce)
    return idx, weights, aux


def moe_dense(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Einsum one-hot dispatch with capacity (GShard/MaxText 'dropping').

    x: [B, S, D].  Tokens are grouped into chunks of ``group`` along S so
    the dispatch tensor [B, n_g, g, E, C] stays modest; its size (and
    FLOPs) scale with g*k*cf — see DESIGN.md and EXPERIMENTS.md §Perf.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    group = min(getattr(cfg, "moe_group_size", 1024), S)
    n_g = max(S // group, 1)
    xg = x.reshape(B * n_g, group, D)
    idx, w, aux = _router_probs(p, xg, cfg)           # [G, g, K]
    C = max(int(math.ceil(group * K / E * cfg.capacity_factor)), K)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)           # [G, g, K, E]
    # position of each (token, k) within its expert queue (GShard cumsum;
    # f32 is exact for the integer-valued counts involved)
    pos_e = jnp.cumsum(onehot.reshape(xg.shape[0], -1, E), axis=1).reshape(
        xg.shape[0], group, K, E
    ) - onehot
    pos = jnp.einsum("gtke,gtke->gtk", pos_e, onehot).astype(jnp.int32)
    # masks in activation dtype: the [G, g/E, C, ...] tensors below are
    # the big ones — keeping them bf16 halves MoE activation memory
    keep = (pos < C).astype(x.dtype)[..., None] * onehot.astype(x.dtype)
    posc = jax.nn.one_hot(pos, C, dtype=x.dtype)                  # [G, g, K, C]
    dispatch = jnp.einsum("gtke,gtkc->gtec", keep, posc)          # [G, g, E, C]
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", w.astype(x.dtype), keep, posc)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xg)              # [G, E, C, D]
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["w1"]))
    h = h * jnp.einsum("gecd,edf->gecf", xin, p["w3"])
    xout = jnp.einsum("gecf,efd->gecd", h, p["w2"])               # [G, E, C, D]
    y = jnp.einsum("gtec,gecd->gtd", combine, xout)
    y = y.reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], x)
    return y, aux


def moe_scatter(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based (argsort + gather/scatter) dispatch: no O(g^2) one-hot.

    Beyond-paper optimization (EXPERIMENTS.md §Perf): replaces the
    dispatch einsum's 2*T*(g*k*cf)*D FLOPs with O(T*k) index plumbing.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    xf = x.reshape(T, D)
    idx, w, aux = _router_probs(p, xf, cfg)           # [T, K]
    C = max(int(math.ceil(T * K / E * cfg.capacity_factor)), K)

    flat_e = idx.reshape(-1)                           # [T*K]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position within expert segment
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_in_e = jnp.arange(T * K) - seg_start[sorted_e]
    tok = order // K
    keep = pos_in_e < C
    slot = sorted_e * C + jnp.where(keep, pos_in_e, 0)

    buf = jnp.zeros((E * C, D), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xf[tok], 0))
    xin = buf.reshape(E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", xin, p["w3"])
    xout = jnp.einsum("ecf,efd->ecd", h, p["w2"]).reshape(E * C, D)

    gathered = jnp.where(keep[:, None], xout[slot], 0)         # [T*K, D]
    wk = w.reshape(-1)[order]
    contrib = gathered * wk[:, None]
    y = jnp.zeros((T, D), x.dtype).at[tok].add(contrib)
    y = y.reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], x)
    return y, aux


def moe_layer(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.moe_impl == "a2a" and x.shape[1] > 1:
        from repro.parallel.moe_a2a import ep_armed, moe_a2a

        if ep_armed(cfg):
            return moe_a2a(p, x, cfg)
        # no armed EP mesh (single-device tests): dense fallback
        return moe_dense(p, x, cfg)
    # decode (S == 1): the weight-gathered a2a would re-gather every
    # expert's weights per token step (~28 GB/step for deepseek) — the
    # dense dispatch is tiny at one token per sequence and keeps expert
    # weights resident (EXPERIMENTS §Perf-B note).
    if cfg.moe_impl == "scatter":
        return moe_scatter(p, x, cfg)
    return moe_dense(p, x, cfg)


# ---------------------------------------------------------------------------
# MLA (deepseek-v2 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(rng, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    qk = cfg.qk_nope_head_dim
    qr = cfg.qk_rope_head_dim
    vh = cfg.v_head_dim
    r = _split(rng, 8)
    return {
        "wq_a": dense_init(r[0], (d, cfg.q_lora_rank), dtype=dtype),
        "q_norm": jnp.ones((cfg.q_lora_rank,), dtype),
        "wq_b": dense_init(r[1], (cfg.q_lora_rank, h * (qk + qr)), dtype=dtype),
        "wkv_a": dense_init(r[2], (d, cfg.kv_lora_rank), dtype=dtype),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
        "wk_rope": dense_init(r[3], (d, qr), dtype=dtype),
        "wkv_b": dense_init(r[4], (cfg.kv_lora_rank, h * (qk + vh)), dtype=dtype),
        "wo": dense_init(r[5], (h * vh, d), dtype=dtype),
    }


def _mla_qkv(p: Params, x: jnp.ndarray, positions, cfg: ModelConfig):
    """Projects MLA q/k/v WITHOUT materializing per-head full K.

    Returns (q_nope [B,H,S,qk], q_rope [B,H,S,qr], k_nope [B,H,S,qk],
    k_rope [B,S,qr] shared-head, v [B,H,S,vh], ckv).  Scores are computed
    as the *sum of two einsums* — concatenating [k_nope | broadcast
    k_rope] is mathematically identical but wrecks SPMD propagation (a
    1-head broadcast + concat forced XLA to all-gather full-head f32 K:
    measured 32 GB/layer/device on deepseek train; EXPERIMENTS §Perf-2b).
    """
    B, S, _ = x.shape
    h = cfg.n_heads
    qk, qr, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, S, h, qk + qr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    cos, sin = make_rope(positions, qr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    ckv = rms_norm(x @ p["wkv_a"], p["kv_norm"], cfg.norm_eps)    # [B,S,r_kv]
    k_rope = (x @ p["wk_rope"]).reshape(B, S, 1, qr).transpose(0, 2, 1, 3)
    k_rope = apply_rope(k_rope, cos, sin).squeeze(1)               # [B,S,qr]
    kv = (ckv @ p["wkv_b"]).reshape(B, S, h, qk + vh).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :qk], kv[..., qk:]
    # pin head sharding: the slice/transpose chain above loses the spec
    # during backward propagation and XLA falls back to full-head f32
    # all-gathers (measured 21 GB/layer/device; EXPERIMENTS §Perf-2b).
    q_nope = sp_shard_heads(q_nope, h)
    q_rope = sp_shard_heads(q_rope, h)
    k_nope = sp_shard_heads(k_nope, h)
    v = sp_shard_heads(v, h)
    return q_nope, q_rope, k_nope, k_rope, v, ckv


def _mla_sdpa(q_nope, q_rope, k_nope, k_rope, v, *, q_positions,
              kv_positions, sm_scale, q_chunk: int = 0):
    """Two-term MLA attention with optional blockwise q-chunking."""
    B, H, Sq, _ = q_nope.shape
    Sk = k_nope.shape[2]

    def block(qn, qr_, qp):
        s = (jnp.einsum("bhqd,bhsd->bhqs", qn, k_nope,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhqd,bsd->bhqs", qr_, k_rope,
                          preferred_element_type=jnp.float32)) * sm_scale
        rel = qp[:, None] - kv_positions[None, :]
        s = jnp.where(rel[None, None] >= 0, s, -1e30)
        probs = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqs,bhsd->bhqd", probs, v)

    if q_chunk and Sq > 2 * q_chunk and Sq % q_chunk == 0:
        n = Sq // q_chunk
        qn = q_nope.reshape(B, H, n, q_chunk, -1).transpose(2, 0, 1, 3, 4)
        qr_ = q_rope.reshape(B, H, n, q_chunk, -1).transpose(2, 0, 1, 3, 4)
        qp = q_positions.reshape(n, q_chunk)
        out = jax.lax.map(lambda t: jax.checkpoint(block)(*t), (qn, qr_, qp))
        return out.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, -1)
    return block(q_nope, q_rope, q_positions)


def mla_attention(
    p: Params, x: jnp.ndarray, cfg: ModelConfig,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Training/prefill MLA.  Cache is the *compressed* (ckv, k_rope)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q_nope, q_rope, k_nope, k_rope, v, ckv = _mla_qkv(p, x, positions, cfg)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    out = _mla_sdpa(q_nope, q_rope, k_nope, k_rope, v,
                    q_positions=positions, kv_positions=positions,
                    sm_scale=scale, q_chunk=cfg.attn_q_chunk)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.v_head_dim)
    return out @ p["wo"], {"ckv": ckv, "k_rope": k_rope}


def mla_attention_decode_absorbed(
    p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
    pos: jnp.ndarray, cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Matrix-absorbed MLA decode (§Perf hillclimb; DeepSeek-V2 paper §2.1.2).

    The naive decode re-expands per-head K/V from the latent cache —
    an O(S * H * r_kv * (qk + vh)) matmul and an O(B * H * S * (qk + vh))
    buffer per layer.  Absorbing ``wkv_b`` into the query/output paths
    keeps *everything* in the rank-r_kv latent space:

        scores = (q_nope @ W_uk) @ ckv^T + q_rope @ k_rope^T
        out    = (probs @ ckv) @ W_uv

    Per-token work on the S axis drops from H*S*(qk+vh+expansion) to
    H*S*(r_kv + qr) + H*S*r_kv, and no [B, H, S, .] tensor is ever built.
    """
    B = x.shape[0]
    h = cfg.n_heads
    qk, qr, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank

    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, 1, h, qk + qr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    cos, sin = make_rope(pos[None], qr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    ckv_new = rms_norm(x @ p["wkv_a"], p["kv_norm"], cfg.norm_eps)
    kr_new = (x @ p["wk_rope"]).reshape(B, 1, 1, qr).transpose(0, 2, 1, 3)
    kr_new = apply_rope(kr_new, cos, sin).squeeze(1)
    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))

    # wkv_b [r_kv, H*(qk+vh)] -> W_uk [H, r_kv, qk], W_uv [H, r_kv, vh]
    wkv_b = p["wkv_b"].reshape(r_kv, h, qk + vh)
    w_uk, w_uv = wkv_b[..., :qk], wkv_b[..., qk:]

    q_lat = jnp.einsum("bhqd,rhd->bhqr", q_nope, w_uk)        # [B,H,1,r_kv]
    # bf16 operands, f32 accumulation: a post-sum astype(f32) would let
    # XLA hoist the convert into the inputs, materializing f32 copies of
    # the whole latent cache + weights (measured +10 GB/device).
    scores = (
        jnp.einsum("bhqr,bsr->bhqs", q_lat, ckv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhqd,bsd->bhqs", q_rope, k_rope,
                     preferred_element_type=jnp.float32)
    ) / math.sqrt(qk + qr)
    S_max = ckv.shape[1]
    valid = jnp.arange(S_max) <= pos
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    ctx = jnp.einsum("bhqs,bsr->bhqr", probs, ckv)            # [B,H,1,r_kv]
    out = jnp.einsum("bhqr,rhd->bhqd", ctx, w_uv)             # [B,H,1,vh]
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, h * vh)
    return out @ p["wo"], {"ckv": ckv, "k_rope": k_rope}


def mla_attention_decode(
    p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
    pos: jnp.ndarray, cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Decode with the compressed cache: ckv [B, S_max, r_kv],
    k_rope [B, S_max, qr].  K/V are re-expanded from the latent (the
    'naive' MLA decode; ``cfg.mla_absorb`` switches to the absorbed
    fast path — see :func:`mla_attention_decode_absorbed`)."""
    if cfg.mla_absorb:
        return mla_attention_decode_absorbed(p, x, cache, pos, cfg)
    B = x.shape[0]
    h = cfg.n_heads
    qk, qr, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, 1, h, qk + qr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    cos, sin = make_rope(pos[None], qr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    ckv_new = rms_norm(x @ p["wkv_a"], p["kv_norm"], cfg.norm_eps)
    kr_new = (x @ p["wk_rope"]).reshape(B, 1, 1, qr).transpose(0, 2, 1, 3)
    kr_new = apply_rope(kr_new, cos, sin).squeeze(1)
    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))

    S_max = ckv.shape[1]
    kv = (ckv @ p["wkv_b"]).reshape(B, S_max, h, qk + vh).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :qk], kv[..., qk:]
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None], (B, h, S_max, qr))], axis=-1)
    scores = jnp.einsum("bhqd,bhsd->bhqs", q_full, k_full).astype(jnp.float32)
    scores = scores / math.sqrt(qk + qr)
    valid = jnp.arange(S_max) <= pos
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bhsd->bhqd", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, h * vh)
    return out @ p["wo"], {"ckv": ckv, "k_rope": k_rope}
