from .base import SHAPES, ModelConfig, ShapeSpec, shape_applicable  # noqa: F401
from .registry import ARCH_IDS, ARCHS, get_config  # noqa: F401
