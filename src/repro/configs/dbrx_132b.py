"""dbrx-132b — exact assigned config.

[hf:databricks/dbrx-base] 40L d6144 48H kv=8 vocab 100352,
16 experts top-4 with d_ff_expert 10752 (fine-grained).
"""

from .base import ModelConfig

# [hf:databricks/dbrx-base] 40L d6144 48H kv=8 vocab 100352,
# 16 experts top-4 with d_ff_expert 10752 (fine-grained).
CONFIG = ModelConfig(
    name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=10752, vocab_size=100352,
    head_dim=128, rope_theta=500000.0,
    n_experts=16, moe_top_k=4, d_ff_expert=10752,
    # tuned (EXPERIMENTS §Perf-2): shard_map all-to-all EP; falls back
    # to the dense einsum dispatch off-mesh
    moe_impl="a2a",
)
