"""qwen2-0.5b — exact assigned config.

[arXiv:2407.10671] 24L d896 14H GQA kv=2 dff 4864 vocab 151936, QKV bias
"""

from .base import ModelConfig

# [arXiv:2407.10671] 24L d896 14H GQA kv=2 dff 4864 vocab 151936, QKV bias
CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab_size=151936,
    head_dim=64, rope_theta=1000000.0, qkv_bias=True, tie_embeddings=True,
)
