"""glm4-9b — exact assigned config.

[hf:THUDM/glm-4-9b] 40L d4096 32H GQA kv=2 dff 13696 vocab 151552, RoPE
"""

from .base import ModelConfig

# [hf:THUDM/glm-4-9b] 40L d4096 32H GQA kv=2 dff 13696 vocab 151552, RoPE
CONFIG = ModelConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab_size=151552,
    head_dim=128, rope_theta=10000.0, qkv_bias=True,
    # tuned (EXPERIMENTS §Perf-1): coarser q-chunks cut per-chunk
    # collective overhead 2.4x while staying within HBM
    attn_q_chunk=1024,
)
