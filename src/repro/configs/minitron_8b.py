"""minitron-8b — exact assigned config.

[arXiv:2407.14679] pruned nemotron: 32L d4096 32H kv=8 dff 16384 v256000
"""

from .base import ModelConfig

# [arXiv:2407.14679] pruned nemotron: 32L d4096 32H kv=8 dff 16384 v256000
CONFIG = ModelConfig(
    name="minitron-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=16384, vocab_size=256000,
    head_dim=128, rope_theta=10000.0,
    # tuned (EXPERIMENTS §Perf-1): coarser q-chunks cut per-chunk
    # collective overhead 2.4x while staying within HBM
    attn_q_chunk=1024,
)
