"""llava-next-mistral-7b — exact assigned config.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] mistral-7B backbone:
32L d4096 32H kv=8 dff 14336 vocab 32000; anyres tiling is a stub
(patch embeddings prepended to the sequence).
"""

from .base import ModelConfig

# [hf:llava-hf/llava-v1.6-mistral-7b-hf] mistral-7B backbone:
# 32L d4096 32H kv=8 dff 14336 vocab 32000; anyres tiling is a stub
# (patch embeddings prepended to the sequence).
CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=32000,
    head_dim=128, rope_theta=1000000.0, n_img_tokens=576,
    # tuned (EXPERIMENTS §Perf-1): coarser q-chunks cut per-chunk
    # collective overhead 2.4x while staying within HBM
    attn_q_chunk=1024,
)
