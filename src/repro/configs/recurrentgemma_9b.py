"""recurrentgemma-9b — exact assigned config.

[arXiv:2402.19427] Griffin-arch: 38L d4096 16H MQA kv=1 dff 12288
v256000; RG-LRU + local attention window 2048, pattern (R, R, A).
"""

from .base import ModelConfig

# [arXiv:2402.19427] Griffin-arch: 38L d4096 16H MQA kv=1 dff 12288
# v256000; RG-LRU + local attention window 2048, pattern (R, R, A).
CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab_size=256000,
    head_dim=256, attn_window=2048, block_pattern=("R", "R", "A"),
    rglru_conv_width=4, rope_theta=10000.0,
)
