"""deepseek-v2-236b — exact assigned config.

[arXiv:2405.04434] 60L d5120 128H, MLA (kv_lora 512, q_lora 1536,
rope_hd 64, nope_hd 128, v_hd 128), MoE: 160 routed (dff 1536) top-6
+ 2 shared, first layer dense (dff 12288 -> d_ff).
"""

from .base import ModelConfig

# [arXiv:2405.04434] 60L d5120 128H, MLA (kv_lora 512, q_lora 1536,
# rope_hd 64, nope_hd 128, v_hd 128), MoE: 160 routed (dff 1536) top-6
# + 2 shared, first layer dense (dff 12288 -> d_ff).
CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv_heads=128, d_ff=12288, vocab_size=102400,
    head_dim=192, rope_theta=10000.0,
    n_experts=160, moe_top_k=6, d_ff_expert=1536, n_shared_experts=2,
    n_dense_layers=1,
    # tuned (EXPERIMENTS §Perf-2/B): a2a EP + matrix-absorbed MLA decode
    moe_impl="a2a", mla_absorb=True,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
)
