"""Model / run configuration dataclasses.

One :class:`ModelConfig` describes any of the assigned architectures; the
``family`` field selects the model implementation:

* ``dense``  — decoder-only transformer (GQA, RoPE): glm4, qwen2, granite,
  minitron, and the llava/mistral backbone.
* ``moe``    — dense transformer with MoE FFN (dbrx) or MLA+MoE (deepseek).
* ``ssm``    — RWKV6 "Finch" (attention-free, data-dependent decay).
* ``hybrid`` — RecurrentGemma (RG-LRU recurrent blocks + local attention).
* ``encdec`` — Whisper (audio encoder + text decoder, conv frontend stub).
* ``vlm``    — LLaVA-NeXT (dense backbone + anyres patch-embedding stub).

``smoke()`` derives a reduced config of the same family for CPU tests;
full configs are only ever lowered via the dry-run (ShapeDtypeStruct, no
allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


#: The assigned LM-family shape set (identical across the 10 archs).
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    qkv_bias: bool = False            # qwen2
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0         # deepseek shared experts
    n_dense_layers: int = 0           # leading dense (non-MoE) layers
    capacity_factor: float = 1.25
    moe_impl: str = "dense"           # 'dense' (einsum dispatch) | 'scatter'
    moe_group_size: int = 1024        # tokens per dispatch group (dense impl)

    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False          # matrix-absorbed decode (§Perf)

    # --- hybrid / local attention ---
    attn_window: int = 0              # 0 = full; >0 = sliding window
    block_pattern: Tuple[str, ...] = ()  # e.g. ('R','R','A') cycle (hybrid)
    rglru_conv_width: int = 4

    # --- rwkv6 ---
    rwkv_head_dim: int = 64
    wkv_impl: str = "xla"             # 'xla' scan | 'kernel' (Pallas chunked)

    # --- encdec (whisper) ---
    n_encoder_layers: int = 0
    n_audio_ctx: int = 1500           # encoder positions (stub frontend)
    max_positions: int = 32768        # learned decoder positional table
                                      # (whisper ships 448; sized for the
                                      # assigned 32k decode shapes)

    # --- vlm (llava) ---
    n_img_tokens: int = 576           # patch embeddings per image (stub)

    # --- execution ---
    dtype: str = "bfloat16"
    attention_impl: str = "xla"       # 'xla' | 'flash' (Pallas, TPU only)
    remat: str = "block"              # 'none' | 'block'
    use_scan: bool = True             # scan over layers (small HLO)
    loss_chunk_size: int = 512        # chunked CE: never materialize [B,S,V]
    attn_q_chunk: int = 256           # blockwise attention q-chunk (0 = off)
    sequence_parallel: bool = True    # SP: shard saved activations over TP
    hoist_kv_gather: bool = True      # gather K/V once, not per q-chunk

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if decode at 500k context is sub-quadratic / O(window)."""
        return self.family in ("ssm", "hybrid")

    @property
    def n_rec_layers(self) -> int:
        if not self.block_pattern:
            return 0
        full, rem = divmod(self.n_layers, len(self.block_pattern))
        pat = list(self.block_pattern) * full + list(self.block_pattern)[:rem]
        return sum(1 for b in pat if b == "R")

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind sequence for hybrid models ('R'/'A')."""
        if not self.block_pattern:
            return tuple("A" for _ in range(self.n_layers))
        full, rem = divmod(self.n_layers, len(self.block_pattern))
        pat = list(self.block_pattern) * full + list(self.block_pattern)[:rem]
        return tuple(pat)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.use_mla:
            qk_hd = self.qk_nope_head_dim + self.qk_rope_head_dim
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * qk_hd
                + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.family == "ssm":
            attn = 4 * d * d + d * self.d_model // 16  # rwkv time-mix approx
        dense_ffn = 3 * d * f
        per_layer = attn + dense_ffn
        total = emb + L * (attn + 0)
        if self.n_experts:
            expert_ffn = 3 * d * self.d_ff_expert
            shared = self.n_shared_experts * expert_ffn
            moe_layers = L - self.n_dense_layers
            total += (
                self.n_dense_layers * dense_ffn
                + moe_layers * (self.n_experts * expert_ffn + shared + d * self.n_experts)
            )
        else:
            total += L * dense_ffn
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        expert_ffn = 3 * d * self.d_ff_expert
        moe_layers = self.n_layers - self.n_dense_layers
        inactive = moe_layers * (self.n_experts - self.moe_top_k) * expert_ffn
        return int(self.param_count() - inactive)

    # ------------------------------------------------------------------
    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            family=self.family,
            n_layers=min(self.n_layers, 2 if not self.block_pattern else 3),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            rope_theta=self.rope_theta,
            qkv_bias=self.qkv_bias,
            attn_window=min(self.attn_window, 32) if self.attn_window else 0,
            block_pattern=self.block_pattern,
            rglru_conv_width=self.rglru_conv_width,
            rwkv_head_dim=16,
            dtype="float32",
            attention_impl="xla",
            use_scan=self.use_scan,
        )
        if self.n_experts:
            kw.update(
                n_experts=4, moe_top_k=min(self.moe_top_k, 2), d_ff_expert=32,
                n_shared_experts=min(self.n_shared_experts, 1),
                n_dense_layers=min(self.n_dense_layers, 1),
                capacity_factor=self.capacity_factor, moe_impl=self.moe_impl,
            )
        if self.use_mla:
            kw.update(
                use_mla=True, q_lora_rank=32, kv_lora_rank=16,
                qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
                head_dim=24,
            )
        if self.family == "encdec":
            kw.update(n_encoder_layers=2, n_audio_ctx=16)
        if self.family == "vlm":
            kw.update(n_img_tokens=8)
        return ModelConfig(**kw)


def shape_applicable(config: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs or is a documented skip."""
    if shape.name == "long_500k" and not config.supports_long_context:
        return False, (
            "full-attention arch: O(S^2) attention at 524,288 context is "
            "infeasible; long_500k runs only for SSM/hybrid (DESIGN.md §4)"
        )
    return True, ""
