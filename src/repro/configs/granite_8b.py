"""granite-8b — exact assigned config.

[arXiv:2405.04324] llama-arch code model: 36L d4096 32H kv=8 dff 14336
"""

from .base import ModelConfig

# [arXiv:2405.04324] llama-arch code model: 36L d4096 32H kv=8 dff 14336
CONFIG = ModelConfig(
    name="granite-8b", family="dense", n_layers=36, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=49152,
    head_dim=128, rope_theta=10000000.0,
    # tuned (EXPERIMENTS §Perf-1): coarser q-chunks cut per-chunk
    # collective overhead 2.4x while staying within HBM
    attn_q_chunk=1024,
)
