"""The 10 assigned architectures (one module per arch, exact public configs).

Each arch is selectable via ``--arch <id>`` in the launchers; sources are
cited in the per-arch modules ([hf:...] / [arXiv:...] as assigned).
"""

from __future__ import annotations

from typing import Dict

from . import (
    dbrx_132b,
    deepseek_v2_236b,
    glm4_9b,
    granite_8b,
    llava_next_mistral_7b,
    minitron_8b,
    qwen2_0_5b,
    recurrentgemma_9b,
    rwkv6_1_6b,
    whisper_small,
)
from .base import ModelConfig

__all__ = ["ARCHS", "get_config", "ARCH_IDS"]

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        glm4_9b,
        qwen2_0_5b,
        granite_8b,
        minitron_8b,
        rwkv6_1_6b,
        recurrentgemma_9b,
        dbrx_132b,
        deepseek_v2_236b,
        whisper_small,
        llava_next_mistral_7b,
    )
}

ARCH_IDS = tuple(ARCHS)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]
