"""whisper-small — exact assigned config.

[arXiv:2212.04356] enc-dec 12L+12L d768 12H dff 3072 vocab 51865;
conv frontend is a stub (input_specs provides frame embeddings).
"""

from .base import ModelConfig

# [arXiv:2212.04356] enc-dec 12L+12L d768 12H dff 3072 vocab 51865;
# conv frontend is a stub (input_specs provides frame embeddings).
CONFIG = ModelConfig(
    name="whisper-small", family="encdec", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=51865,
    head_dim=64, n_encoder_layers=12, n_audio_ctx=1500,
)
