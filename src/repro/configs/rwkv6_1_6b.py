"""rwkv6-1.6b — exact assigned config.

[arXiv:2404.05892] Finch: 24L d2048 attn-free dff 7168 vocab 65536
"""

from .base import ModelConfig

# [arXiv:2404.05892] Finch: 24L d2048 attn-free dff 7168 vocab 65536
CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=7168, vocab_size=65536,
    head_dim=64, rwkv_head_dim=64,
)
