"""Cloud Collectives reproduction, grown into a production-shaped system.

The one-call entry point is the Session facade::

    from repro import Session, SessionConfig

    with Session(SessionConfig.from_dict({
            "fabric": {"kind": "datacenter", "nodes": 64},
            "mesh": {"shape": "8x8"}})) as s:
        applied = s.apply()          # probe -> plan -> apply in one chain

From a shell, the same lifecycle is ``python -m repro {probe,plan,train,
serve,bench}`` (or the ``repro`` console script after ``pip install -e .``).

Exports are lazy: importing :mod:`repro` never pulls in jax or numpy;
the first attribute access resolves against the owning submodule.
"""

from importlib import import_module
from typing import TYPE_CHECKING

__version__ = "0.3.0"

#: public name -> owning module (resolved lazily on first access)
_EXPORTS = {
    # session facade
    "Session": "repro.session",
    "SessionConfig": "repro.session",
    "SessionError": "repro.session",
    "AppliedPlan": "repro.session",
    "FabricConfig": "repro.session",
    "ProbeConfig": "repro.session",
    "SolverConfig": "repro.session",
    "CacheConfig": "repro.session",
    "DriftConfig": "repro.session",
    "MeshConfig": "repro.session",
    "ObsConfig": "repro.session",
    "train_mix": "repro.session",
    "serve_mix": "repro.session",
    # observability
    "Tracer": "repro.obs",
    "MetricsRegistry": "repro.obs",
    "WorkloadRecorder": "repro.obs",
    "WorkloadTrace": "repro.obs",
    # collective IR
    "CollectiveOp": "repro.collective",
    "Program": "repro.collective",
    "AnalyticExecutor": "repro.collective",
    "SimExecutor": "repro.collective",
    "JaxExecutor": "repro.collective",
    "compile_op": "repro.collective",
    "apply_permutation": "repro.collective",
    # plan subsystem
    "CollectiveRequest": "repro.plan",
    "JobMix": "repro.plan",
    "Plan": "repro.plan",
    "PlanEntry": "repro.plan",
    "PlanCompiler": "repro.plan",
    "PlanCache": "repro.plan",
    "PlanningService": "repro.plan",
    "SolveBudget": "repro.plan",
    "DriftMonitor": "repro.plan",
    "fabric_fingerprint": "repro.plan",
    # fabric subsystem
    "Fabric": "repro.fabric",
    "make_datacenter": "repro.fabric",
    "make_tpu_fleet": "repro.fabric",
    "scramble": "repro.fabric",
    "ProbeResult": "repro.fabric",
    "probe_fabric": "repro.fabric",
    "cost_matrix": "repro.fabric",
    "combine_cost": "repro.fabric",
    "HierarchyModel": "repro.fabric",
    "infer_hierarchy": "repro.fabric",
    "SparseProbeResult": "repro.fabric",
    "sparse_probe_fabric": "repro.fabric",
    "refresh_sparse": "repro.fabric",
    # faults + resilience
    "FaultEvent": "repro.faults",
    "FaultSchedule": "repro.faults",
    "FaultyFabric": "repro.faults",
    "ProbeTimeout": "repro.faults",
    "RetryPolicy": "repro.faults",
    "RetryError": "repro.faults",
    "call_with_retries": "repro.faults",
    "HealthTracker": "repro.faults",
    "recover_plan": "repro.faults",
    # core pipeline
    "optimize_rank_order": "repro.core",
    "optimize_rank_order_hierarchical": "repro.core",
    "hierarchical_perm": "repro.core",
    "optimize_mesh_assignment": "repro.core",
    "MeshPlan": "repro.core",
}

__all__ = sorted(_EXPORTS) + ["__version__"]

if TYPE_CHECKING:  # pragma: no cover - static analysis only
    from repro.core import (  # noqa: F401
        MeshPlan,
        hierarchical_perm,
        optimize_mesh_assignment,
        optimize_rank_order,
        optimize_rank_order_hierarchical,
    )
    from repro.fabric import (  # noqa: F401
        Fabric,
        HierarchyModel,
        ProbeResult,
        SparseProbeResult,
        combine_cost,
        cost_matrix,
        infer_hierarchy,
        make_datacenter,
        make_tpu_fleet,
        probe_fabric,
        refresh_sparse,
        scramble,
        sparse_probe_fabric,
    )
    from repro.plan import (  # noqa: F401
        CollectiveRequest,
        DriftMonitor,
        JobMix,
        Plan,
        PlanCache,
        PlanCompiler,
        PlanEntry,
        PlanningService,
        SolveBudget,
        fabric_fingerprint,
    )
    from repro.session import (  # noqa: F401
        AppliedPlan,
        CacheConfig,
        DriftConfig,
        FabricConfig,
        MeshConfig,
        ProbeConfig,
        Session,
        SessionConfig,
        SessionError,
        SolverConfig,
        serve_mix,
        train_mix,
    )


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    value = getattr(import_module(module), name)
    globals()[name] = value            # cache for subsequent accesses
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
