"""repro.plan — collective plan compiler, plan cache, planning service.

Most applications should use the :class:`repro.session.Session` facade
(or ``python -m repro plan``), which owns a PlanningService plus cache
and wires drift re-plans automatically; the manual pipeline below
remains the mechanical layer the session drives.

End-to-end::

    fabric  = make_tpu_fleet(...)                    # or a live cluster
    probed  = probe_fabric(fabric)                   # paper §IV-B probing
    mix     = JobMix.from_hlo(hlo_text)              # or declared directly
    service = PlanningService(PlanCompiler(fabric=fabric),
                              PlanCache(store_dir=".plan_cache"))
    plan    = service.request(probed, mix, mesh_shape=(16, 16),
                              axis_names=("data", "model"))
    mesh    = make_planned_mesh(plan)                # launch integration
    entry   = plan.lookup("all-to-all", 4e6)         # per-op consumers

See DESIGN.md §5 for the architecture.
"""

from .cache import (  # noqa: F401
    DriftMonitor,
    DriftReport,
    FabricFingerprint,
    PlanCache,
    fabric_fingerprint,
)
from .compiler import (  # noqa: F401
    CollectiveRequest,
    JobMix,
    Plan,
    PlanCompiler,
    PlanEntry,
    SolveBudget,
    candidate_algorithms,
    size_bucket,
)
from .service import PlanningService  # noqa: F401
