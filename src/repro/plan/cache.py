"""Fingerprint-keyed plan cache with drift-based invalidation.

**Fingerprinting.**  A plan's rank permutations refer to concrete node
ids, so the fingerprint must be *order-sensitive* (a re-scrambled IP
list must not hit a stale plan) yet *noise-robust* (re-probing the same
fabric must hit the cache).  Exact hashing of quantized costs is
boundary-brittle — with n^2 elements some always sit on a bin edge — so
:func:`fabric_fingerprint` builds a **sketch**: per-node log2 row
medians (order-sensitive, median-of-n is stable under per-pair probe
noise) plus the global log2 percentile profile (shape of the cost
distribution).  Cache lookups match sketches fuzzily
(:meth:`FabricFingerprint.matches`, max component distance below
``tol`` octaves); the exact ``digest`` — a coarse hash — is only an id
for filenames and logs.

**Cache.**  :class:`PlanCache` is a thread-safe in-memory LRU over
(fingerprint, request key) with an optional JSON directory store:
entries persist across processes as one self-describing file per plan
(the serialized :class:`~repro.plan.compiler.Plan` embeds its
fingerprint, so the store can be re-matched fuzzily after reload).

**Drift.**  :class:`DriftMonitor` wires invalidation to
:class:`repro.core.dynamic.AdaptiveReranker`: one reranker per plan
entry watches refreshed cost matrices (re-probes, TCP_INFO-style
monitoring, straggler detectors); when an entry's order degrades past
the reranker threshold, the monitor patches the entry with the
reranker's bottleneck-swap repair (cheap hot fix) and invalidates the
cached plan so the next request recompiles from scratch.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import warnings
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.cost_models import make_cost_model
from repro.core.dynamic import AdaptiveReranker

from repro.collective import get_builder

from .compiler import EntryKey, Plan, PlanEntry

__all__ = [
    "FabricFingerprint",
    "fabric_fingerprint",
    "PlanCache",
    "DriftMonitor",
    "DriftReport",
]

#: Default fuzzy-match tolerance in octaves.  Probe noise moves row
#: log-medians by ~0.01 octaves; real structural change (a congested
#: link, a relabeled node) moves them by >= 1.
DEFAULT_TOL = 0.25

_PCTS = (5.0, 25.0, 50.0, 75.0, 95.0)


@dataclasses.dataclass(frozen=True)
class FabricFingerprint:
    """Noise-robust, order-sensitive sketch of a probed cost matrix."""

    n: int
    sketch: Tuple[float, ...]   # [n row log-medians, len(_PCTS) profile terms]
    digest: str                 # coarse stable id (filenames / logs only)

    def matches(self, other: "FabricFingerprint", tol: float = DEFAULT_TOL) -> bool:
        if not isinstance(other, FabricFingerprint) or self.n != other.n:
            return False
        if len(self.sketch) != len(other.sketch):
            return False
        a = np.asarray(self.sketch)
        b = np.asarray(other.sketch)
        return bool(np.max(np.abs(a - b)) < tol)

    def to_dict(self) -> dict:
        return {"n": self.n, "sketch": list(self.sketch), "digest": self.digest}

    @staticmethod
    def from_dict(d: dict) -> "FabricFingerprint":
        return FabricFingerprint(
            n=int(d["n"]),
            sketch=tuple(float(x) for x in d["sketch"]),
            digest=str(d["digest"]),
        )


def _bw_part(bw: Optional[np.ndarray], n: int) -> np.ndarray:
    """Per-node log2 row medians of the bandwidth matrix (vs their own
    median) — shared by the dense and tree sketches."""
    if bw is None or n <= 1:
        return np.zeros(0)
    b = np.asarray(bw, dtype=np.float64)
    rows = []
    for i in range(n):
        v = np.delete(b[i], i)
        v = v[np.isfinite(v) & (v > 0)]
        rows.append(float(np.median(v)) if v.size else np.nan)
    row_bw = np.asarray(rows)
    ok = np.isfinite(row_bw)
    if not ok.any():
        return np.zeros(0)
    bw_med = float(np.median(row_bw[ok]))
    return np.log2(np.where(ok, row_bw, bw_med) / bw_med)


def _row_anchor_parts(c: np.ndarray, med: float,
                      n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-node log2 row medians + anchor columns vs the global median —
    the order-sensitive core shared by the dense and tree sketches."""
    off = ~np.eye(n, dtype=bool)
    row_med = np.array([
        np.median(np.maximum(c[i][off[i]], med * 1e-9)) for i in range(n)
    ]) if n > 1 else np.ones(n)
    row_part = np.log2(row_med / med)
    anchors = sorted({0, n // 3, (2 * n) // 3}) if n > 1 else []
    anchor_part = np.concatenate([
        np.log2(np.maximum(np.delete(c[:, a], a), med * 1e-9) / med)
        for a in anchors
    ]) if anchors else np.zeros(0)
    return row_part, anchor_part


def _tree_fingerprint(c: np.ndarray, bw: Optional[np.ndarray],
                      hierarchy) -> FabricFingerprint:
    """Sketch a hierarchy-completed cost matrix plus its tree structure.

    A sparse probe's matrix is already cluster-median-flattened, so its
    per-node row medians and anchor columns barely move across
    re-probes *of the same probe structure* — the same landmark/refine
    pair set, which is what deterministic probe configs and the
    :func:`repro.fabric.refresh_sparse` drift path re-measure (the
    per-pair noise the dense sketch has to tolerate was medianed away
    at completion time).  A re-randomized landmark set is a different
    probe structure and is not promised to match.  The
    tree contributes structure terms (block count + cut height per
    tier, half-octave weighted so one block splitting/merging under
    noise stays inside the match tolerance while a tier
    appearing/halving does not).  No global percentile profile is
    needed — the structure terms carry the distribution's shape — and
    its absence keeps the tree sketch's length distinct from the dense
    sketch's (2·tiers is even, the dense profile is 5 terms), so the
    two probing modes are separate cache namespaces by construction.
    """
    n = c.shape[0]
    off = ~np.eye(n, dtype=bool)
    vals = c[off]
    pos = vals[vals > 0]
    med = float(np.median(pos)) if pos.size else 1.0
    row_part, anchor_part = _row_anchor_parts(c, med, n)
    struct = []
    for tier, h in zip(hierarchy.tiers, hierarchy.heights):
        struct.append(0.5 * np.log2(max(len(tier), 1)))
        struct.append(0.5 * np.log2(max(h, med * 1e-30) / med))
    sketch = tuple(float(x) for x in np.concatenate(
        [row_part, anchor_part, np.asarray(struct), _bw_part(bw, n)]))
    coarse = tuple(int(x) for x in np.round(np.asarray(sketch) / 1.0))
    digest = hashlib.sha256(repr((n,) + coarse).encode()).hexdigest()[:16]
    return FabricFingerprint(n=n, sketch=sketch, digest=f"hfab{n}-{digest}")


def fabric_fingerprint(cost_matrix: np.ndarray,
                       bw: Optional[np.ndarray] = None,
                       hierarchy=None) -> FabricFingerprint:
    """Sketch the probed cost matrix (see module docstring).

    ``bw``, when probed, contributes per-node log2 row medians of the
    bandwidth matrix so a fabric whose bandwidth collapses with
    latencies unchanged does NOT fuzzily match its old plans (the
    compiler's cost models are bw-aware, so those plans are stale).

    ``hierarchy`` — a non-flat recovered
    :class:`repro.fabric.HierarchyModel` over the same nodes — switches
    to the tree sketch (:func:`_tree_fingerprint`): cheaper components
    (block medians, not n row medians + a percentile profile) that are
    markedly more drift-robust under probe noise.
    """
    c = np.asarray(cost_matrix, dtype=np.float64)
    assert c.ndim == 2 and c.shape[0] == c.shape[1], c.shape
    n = c.shape[0]
    if hierarchy is not None and not getattr(hierarchy, "flat", True) \
            and getattr(hierarchy, "n", -1) == n:
        return _tree_fingerprint(c, bw, hierarchy)
    off = ~np.eye(n, dtype=bool)
    vals = c[off]
    pos = vals[vals > 0]
    med = float(np.median(pos)) if pos.size else 1.0
    # per-node row medians + anchor columns (every node's cost to a few
    # fixed reference nodes — row medians alone are permutation-blind
    # when nodes are statistically alike; who-is-near-whom is not)
    row_part, anchor_part = _row_anchor_parts(c, med, n)
    profile = np.log2(np.maximum(np.percentile(pos, _PCTS) / med, 1e-9)) \
        if pos.size else np.zeros(len(_PCTS))
    bw_part = _bw_part(bw, n)
    sketch = tuple(float(x) for x in
                   np.concatenate([row_part, anchor_part, profile, bw_part]))
    coarse = tuple(int(x) for x in np.round(np.asarray(sketch) / 1.0))
    digest = hashlib.sha256(repr((n,) + coarse).encode()).hexdigest()[:16]
    return FabricFingerprint(n=n, sketch=sketch, digest=f"fab{n}-{digest}")


def _request_tag(request_key: str) -> str:
    return hashlib.sha256(request_key.encode()).hexdigest()[:12]


def _sketch_tag(fingerprint: FabricFingerprint) -> str:
    """Exact-sketch hash: uniquifies cache slots so two fabrics whose
    coarse digests collide (sketches round alike but differ by > tol)
    cannot overwrite each other's plans.  Lookups never use it — they
    match sketches fuzzily — so its boundary-sensitivity is harmless."""
    return hashlib.sha256(
        np.asarray(fingerprint.sketch, dtype=np.float64).tobytes()
    ).hexdigest()[:10]


class PlanCache:
    """Thread-safe LRU + optional persistent JSON store of compiled plans.

    Keys are (fabric fingerprint, request key); fingerprint comparison is
    fuzzy (sketch distance), the request key (job-mix key + mesh shape)
    is exact.
    """

    def __init__(self, capacity: int = 32, store_dir: Optional[str] = None,
                 tol: float = DEFAULT_TOL):
        self.capacity = int(capacity)
        self.store_dir = store_dir
        self.tol = float(tol)
        self._lock = threading.RLock()
        #: insertion-ordered: (digest, request_key) -> Plan
        self._mem: "OrderedDict[Tuple[str, str], Plan]" = OrderedDict()
        self.stats = {"hits": 0, "disk_hits": 0, "misses": 0,
                      "puts": 0, "invalidations": 0}
        if store_dir:
            os.makedirs(store_dir, exist_ok=True)

    # -- core API ---------------------------------------------------------
    def get(self, fingerprint: FabricFingerprint,
            request_key: str = "") -> Optional[Plan]:
        with self._lock:
            for key, plan in reversed(self._mem.items()):
                if key[-1] == request_key and \
                        fingerprint.matches(plan.fingerprint, self.tol):
                    self._mem.move_to_end(key)
                    self.stats["hits"] += 1
                    obs.metrics().counter("plan.cache.hits").inc()
                    return plan
            plan = self._load_from_store(fingerprint, request_key)
            if plan is not None:
                self._insert(plan, request_key)
                self.stats["disk_hits"] += 1
                obs.metrics().counter("plan.cache.disk_hits").inc()
                return plan
            self.stats["misses"] += 1
            obs.metrics().counter("plan.cache.misses").inc()
            return None

    def peek_mem(self, fingerprint: FabricFingerprint,
                 request_key: str = "") -> Optional[Plan]:
        """Memory-only probe: no disk scan, no stats, no LRU touch.

        For callers (the planning service) that must re-check under
        their own lock without serializing everyone behind store I/O.
        """
        with self._lock:
            for key, plan in reversed(self._mem.items()):
                if key[-1] == request_key and \
                        fingerprint.matches(plan.fingerprint, self.tol):
                    return plan
            return None

    def put(self, plan: Plan, request_key: str = "") -> None:
        with self._lock:
            self._insert(plan, request_key)
            self.stats["puts"] += 1
            obs.metrics().counter("plan.cache.puts").inc()
            if self.store_dir:
                path = self._path(plan.fingerprint, request_key)
                tmp = path + ".tmp"
                with open(tmp, "w") as f:
                    f.write(plan.to_json())
                os.replace(tmp, path)

    def invalidate(self, fingerprint: FabricFingerprint,
                   request_key: Optional[str] = None) -> int:
        """Drop every plan whose fingerprint fuzzily matches.

        ``request_key=None`` (drift semantics: the *fabric* changed)
        drops all mixes compiled against the fabric; a specific key
        drops just that plan.  Returns the number of entries dropped.
        """
        dropped = 0
        with self._lock:
            for key in list(self._mem):
                plan = self._mem[key]
                if request_key is not None and key[-1] != request_key:
                    continue
                if fingerprint.matches(plan.fingerprint, self.tol):
                    del self._mem[key]
                    dropped += 1
            if self.store_dir:
                tag = None if request_key is None else _request_tag(request_key)
                for fname, plan_fp, _rk in self._store_index():
                    if tag is not None and not fname.endswith(f"__{tag}.json"):
                        continue
                    if plan_fp is not None and fingerprint.matches(plan_fp, self.tol):
                        try:
                            os.remove(os.path.join(self.store_dir, fname))
                            dropped += 1
                        except OSError:
                            pass
            self.stats["invalidations"] += dropped
            if dropped:
                obs.metrics().counter("plan.cache.invalidations").inc(dropped)
        return dropped

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    # -- internals --------------------------------------------------------
    def _insert(self, plan: Plan, request_key: str) -> None:
        key = (plan.fingerprint.digest, _sketch_tag(plan.fingerprint),
               request_key)
        self._mem[key] = plan
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)

    def _path(self, fingerprint: FabricFingerprint, request_key: str) -> str:
        assert self.store_dir
        return os.path.join(
            self.store_dir,
            f"{fingerprint.digest}-{_sketch_tag(fingerprint)}"
            f"__{_request_tag(request_key)}.json")

    def _quarantine(self, fname: str, error: Exception) -> None:
        """Rename an unreadable store file to ``*.corrupt`` (skipped by
        every future scan) instead of re-parsing — and re-failing — it
        on every lookup.  A truncated write (a crashed process, a full
        disk) must cost one warning, not poison ``get()`` forever."""
        path = os.path.join(self.store_dir, fname)
        try:
            os.replace(path, path + ".corrupt")
            note = f"quarantined as {fname}.corrupt"
        except OSError as rename_err:
            note = f"quarantine rename failed: {rename_err}"
        obs.tracer().event("plan.cache.quarantine", file=fname,
                           error=f"{type(error).__name__}: {error}")
        obs.metrics().counter("plan.cache.quarantines").inc()
        # stacklevel walks _quarantine -> _store_index/_load_from_store
        # -> get/invalidate -> the caller outside the cache (4 frames):
        # the warning should point at whoever asked for the plan, not at
        # cache internals
        warnings.warn(
            f"plan cache store file {fname} is corrupted "
            f"({type(error).__name__}: {error}); {note}",
            RuntimeWarning, stacklevel=4)

    def _store_index(self) -> List[Tuple[str, Optional[FabricFingerprint],
                                         Optional[str]]]:
        if not self.store_dir or not os.path.isdir(self.store_dir):
            return []
        out = []
        for fname in sorted(os.listdir(self.store_dir)):
            if not fname.endswith(".json"):
                continue
            try:
                with open(os.path.join(self.store_dir, fname)) as f:
                    d = json.load(f)
                fp = FabricFingerprint.from_dict(d["fingerprint"])
                rk = str(d.get("mix_key", ""))
                out.append((fname, fp, rk))
            except (OSError, ValueError, KeyError, TypeError) as e:
                self._quarantine(fname, e)
        return out

    def _load_from_store(self, fingerprint: FabricFingerprint,
                         request_key: str) -> Optional[Plan]:
        if not self.store_dir:
            return None
        tag = _request_tag(request_key)
        for fname in sorted(os.listdir(self.store_dir)):
            if not fname.endswith(f"__{tag}.json"):
                continue
            try:
                with open(os.path.join(self.store_dir, fname)) as f:
                    plan = Plan.from_json(f.read())
            except (OSError, ValueError, KeyError, TypeError) as e:
                self._quarantine(fname, e)
                continue
            if fingerprint.matches(plan.fingerprint, self.tol):
                return plan
        return None


@dataclasses.dataclass
class DriftReport:
    stale: bool
    degraded: List[EntryKey]
    repaired: Dict[EntryKey, Tuple[int, ...]]
    invalidated: int = 0


class DriftMonitor:
    """Per-entry :class:`AdaptiveReranker`s that invalidate a cached plan.

    ``reference_cost_matrix`` is the matrix the plan was compiled
    against (it seeds each reranker's reference cost); ``observe`` feeds
    refreshed matrices.  When any entry degrades past ``threshold`` x
    its reference, the entry is hot-patched with the reranker's
    bottleneck-swap repair and the plan is evicted from ``cache``.
    """

    def __init__(self, plan: Plan, reference_cost_matrix: np.ndarray,
                 cache: Optional[PlanCache] = None, threshold: float = 1.15):
        self.plan = plan
        self.cache = cache
        self.threshold = float(threshold)
        self._rerankers: Dict[EntryKey, AdaptiveReranker] = {}
        ref = np.asarray(reference_cost_matrix, dtype=np.float64)
        for key, entry in plan.entries.items():
            factory = self._factory(entry)
            rr = AdaptiveReranker(
                model_factory=factory,
                perm=entry.local_perm.copy(),
                threshold=self.threshold,
            )
            rr.update(self._sub(ref, entry))       # seeds reference_cost
            self._rerankers[key] = rr

    def set_threshold(self, threshold: float) -> None:
        """Adjust drift sensitivity on the live monitor (all rerankers)."""
        self.threshold = float(threshold)
        for rr in self._rerankers.values():
            rr.threshold = float(threshold)

    @staticmethod
    def _sub(c: np.ndarray, entry: PlanEntry) -> np.ndarray:
        g = np.asarray(entry.group, dtype=np.int64)
        return c[np.ix_(g, g)]

    @staticmethod
    def _factory(entry: PlanEntry):
        m_algo = get_builder(entry.algo).cost_model
        kwargs = {"base": entry.algo_kwargs["base"]} \
            if "base" in entry.algo_kwargs else {}

        def make(c: np.ndarray):
            return make_cost_model(m_algo, cost_matrix=c, size_bytes=0.0,
                                   **kwargs)

        return make

    def observe(self, cost_matrix: np.ndarray) -> DriftReport:
        """Feed a refreshed full-fabric cost matrix; see class docstring.

        Rejects malformed observations with :class:`ValueError` — a NaN
        from a corrupted probe sample fed into the rerankers would
        silently poison every solver delta downstream.
        """
        c = np.asarray(cost_matrix, dtype=np.float64)
        if c.ndim != 2 or c.shape[0] != c.shape[1]:
            raise ValueError(
                f"DriftMonitor.observe cost_matrix must be a square "
                f"[n, n] matrix; got shape {c.shape}")
        if c.shape[0] != self.plan.n:
            raise ValueError(
                f"DriftMonitor.observe cost_matrix covers {c.shape[0]} "
                f"nodes but the plan covers {self.plan.n}; after an "
                f"elastic membership change, rebuild the monitor from "
                f"the recovered plan")
        if np.isnan(c).any():
            bad = int(np.isnan(c).sum())
            raise ValueError(
                f"DriftMonitor.observe cost_matrix contains {bad} NaN "
                f"entr{'y' if bad == 1 else 'ies'}; drop or re-probe the "
                f"corrupted samples before observing")
        if (c < 0).any():
            i, j = np.argwhere(c < 0)[0]
            raise ValueError(
                f"DriftMonitor.observe cost_matrix contains negative "
                f"entries (first at [{i}, {j}] = {c[i, j]}); costs are "
                f"times and must be >= 0")
        degraded: List[EntryKey] = []
        repaired: Dict[EntryKey, Tuple[int, ...]] = {}
        for key, rr in self._rerankers.items():
            entry = self.plan.entries[key]
            new_local, changed = rr.update(self._sub(c, entry))
            if changed:
                degraded.append(key)
                g = np.asarray(entry.group, dtype=np.int64)
                new_perm = tuple(int(x) for x in g[np.asarray(new_local)])
                repaired[key] = new_perm
                entry.perm = new_perm              # hot patch until recompile
        stale = bool(degraded)
        invalidated = 0
        if stale:
            self.plan.meta["stale"] = True
            if self.cache is not None:
                invalidated = self.cache.invalidate(self.plan.fingerprint)
        m = obs.metrics()
        m.counter("drift.observations").inc()
        m.gauge("drift.degraded_entries").set(len(degraded))
        # drift score: fraction of plan entries past their reranker
        # threshold this observation — 0.0 on a quiet fabric
        m.gauge("drift.score").set(
            len(degraded) / max(len(self.plan.entries), 1))
        if stale:
            m.counter("drift.stale").inc()
            obs.tracer().event("drift.stale", degraded=len(degraded),
                               invalidated=invalidated)
        return DriftReport(stale=stale, degraded=degraded,
                           repaired=repaired, invalidated=invalidated)
