"""Collective plan compiler: joint (algorithm, chunking, rank order) selection.

The paper's pipeline optimizes one collective at a time, but a real job
issues a *mix* of all-reduce / all-gather / reduce-scatter / all-to-all
at many message sizes, and the best (algorithm, chunk count, rank
permutation) differs per op and size band (PCCL, Won et al.; the MCF
reformulation, Arzani et al.).  This module compiles the whole mix once:

* a :class:`JobMix` declares the collectives a job issues — directly, or
  pulled from optimized HLO via :meth:`JobMix.from_hlo` (which wraps
  :func:`repro.launch.hlo_analysis.parse_collectives`);
* :class:`PlanCompiler` enumerates, per (collective, message-size bucket,
  process group), every feasible registered builder from
  :mod:`repro.collective`, compiles each into a typed ``Program``,
  solves a rank permutation with the vectorized solver
  (:func:`repro.core.solver.solve`) and applies it as an IR pass, and
  scores the candidate programs through the ``Executor`` protocol —
  :class:`repro.collective.SimExecutor` (contention-aware oracle) with
  a fabric, :class:`repro.collective.AnalyticExecutor` without one
  (live probing on real hardware);
* the result is a :class:`Plan`: a JSON-serializable table of
  :class:`PlanEntry` rows plus an optional N-D :class:`MeshPlan`, keyed
  by the fabric fingerprint it was compiled against (see
  :mod:`repro.plan.cache`).

Message sizes are bucketed per octave (log2) so a job's histogram folds
into a handful of entries and cache keys stay canonical.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.collective import (
    AnalyticExecutor,
    CollectiveOp,
    Program,
    SimExecutor,
    apply_permutation,
    candidates as builder_candidates,
    chunk as chunk_pass,
    compile_op,
    get_builder,
    kind_from_op,
)
from repro.core.cost_models import make_cost_model
from repro.core.reorder import (
    MeshPlan,
    hierarchical_perm,
    mesh_axis_cost,
    optimize_mesh_assignment,
)
from repro.core.solver import solve
from repro.fabric import Fabric, HierarchyModel, ProbeResult, combine_cost

__all__ = [
    "CollectiveRequest",
    "JobMix",
    "PlanEntry",
    "Plan",
    "PlanCompiler",
    "SolveBudget",
    "candidate_algorithms",
    "size_bucket",
]

#: Collective ops the compiler plans for.  ``collective-permute`` is
#: deliberately absent: it is already an explicit point-to-point schedule,
#: so there is no algorithm choice to make.
PLANNED_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all")

def candidate_algorithms(op: str, n: int,
                         lowerable_only: bool = False,
                         ) -> List[Tuple[str, Dict[str, int]]]:
    """Feasible (builder name, builder kwargs) pairs for ``op`` at size n.

    Thin alias over :func:`repro.collective.candidates`: power-of-two
    builders are gated on n via each builder's ``feasible`` contract;
    bcube prefers base 4 when n is a power of 4, else base 2.

    With ``lowerable_only`` the list is additionally filtered to
    algorithms :class:`repro.collective.JaxExecutor` can lower to a
    real ppermute schedule — consulted from the executor itself, not a
    hardcoded shape list, so it tracks the generalized lowering (every
    registered builder today).
    """
    if op not in PLANNED_OPS:
        return []
    cands = builder_candidates(op, n)
    if lowerable_only:
        from repro.collective import JaxExecutor
        lowerable = set(JaxExecutor().lowerable_algorithms())
        cands = [(a, kw) for a, kw in cands if a in lowerable]
    return cands


def size_bucket(size_bytes: float) -> int:
    """Octave bucket id: floor(log2(size)).  Sizes < 1 byte collapse to 0."""
    return int(np.floor(np.log2(max(float(size_bytes), 1.0))))


@dataclasses.dataclass(frozen=True)
class CollectiveRequest:
    """One line of a job's collective histogram."""

    op: str                                  # one of PLANNED_OPS
    size_bytes: float                        # per-call payload
    count: float = 1.0                       # calls per step / per query
    group: Optional[Tuple[int, ...]] = None  # node ids; None = all nodes

    def __post_init__(self):
        if self.op not in PLANNED_OPS:
            raise ValueError(f"unknown collective op {self.op!r}; "
                             f"expected one of {PLANNED_OPS}")


@dataclasses.dataclass(frozen=True)
class JobMix:
    """The collective mix one job issues (its message-size histogram)."""

    requests: Tuple[CollectiveRequest, ...]
    name: str = "job"

    def __post_init__(self):
        object.__setattr__(self, "requests", tuple(self.requests))

    def key(self) -> str:
        """Canonical cache/dedup key: bucketed, sorted, group-explicit."""
        rows = sorted(
            (r.op, size_bucket(r.size_bytes),
             list(r.group) if r.group is not None else [])
            for r in self.requests
        )
        return json.dumps(rows, separators=(",", ":"))

    @staticmethod
    def from_hlo(hlo_text: str, name: str = "hlo",
                 scale_loops: bool = True) -> "JobMix":
        """Build a mix from optimized HLO text.

        Wraps :func:`repro.launch.hlo_analysis.parse_collectives`; each
        detail row (comp, op, total_bytes, multiplier) becomes a request
        of ``total_bytes / multiplier`` per call, ``multiplier`` calls.
        ``collective-permute`` rows are skipped (no algorithm choice).
        """
        from repro.launch.hlo_analysis import parse_collectives

        stats = parse_collectives(hlo_text, scale_loops=scale_loops)
        reqs = []
        for _comp, op, total_bytes, mult in stats.details:
            if op not in PLANNED_OPS or total_bytes <= 0 or mult <= 0:
                continue
            reqs.append(CollectiveRequest(
                op=op, size_bytes=total_bytes / mult, count=float(mult)))
        return JobMix(requests=tuple(reqs), name=name)


@dataclasses.dataclass
class PlanEntry:
    """The compiled choice for one (op, size bucket, process group).

    The canonical artifact is the typed ``Program`` the compiler scored
    (rebuildable via :meth:`program`, identity-checked by
    ``program_fingerprint``).  The ``(algo, chunks, perm)`` string-tuple
    fields remain as a deprecating alias of that program — kept for
    JSON compatibility and human-readable plan dumps; new consumers
    should go through :meth:`program` and the Executor protocol.
    """

    op: str
    bucket: int
    size_bytes: float                 # representative payload of the bucket
    group: Tuple[int, ...]            # global node ids, sorted
    algo: str                         # registered repro.collective builder
    algo_kwargs: Dict[str, int]       # e.g. {"base": 4} for bcube
    chunks: int                       # payload split into this many pipelined pieces
    perm: Tuple[int, ...]             # perm[rank] = global node id
    expected_time: float              # oracle seconds per call for the choice
    identity_times: Dict[str, float]  # algo -> oracle seconds at identity order, chunks=1
    solver_cost: float                # cost-model objective of perm
    oracle: str                       # "simulator" | "cost_model"
    program_fingerprint: str = ""     # Program.fingerprint() of the choice
    #: planned overlap-bucket payload (bytes) for this octave: the size
    #: the gradient-bucketing layer (``repro.train.overlap_grads``)
    #: should split a payload of this entry's octave into when fusing
    #: the collective with compute.  0.0 = not planned for this op.
    bucket_bytes: float = 0.0

    @property
    def local_perm(self) -> np.ndarray:
        """perm expressed as positions within ``group`` (rank -> index)."""
        pos = {node: i for i, node in enumerate(self.group)}
        return np.asarray([pos[node] for node in self.perm], dtype=np.int64)

    @property
    def best_identity_time(self) -> float:
        return min(self.identity_times.values())

    def program(self) -> Program:
        """Rebuild the typed ``Program`` this entry's choice denotes.

        Deterministic: compile the registered builder, apply the stored
        permutation and chunking as IR passes.  The result's
        ``fingerprint()`` matches ``program_fingerprint`` for entries
        compiled by this version (older cached plans carry ``""``).
        """
        op = CollectiveOp(kind_from_op(self.op), self.size_bytes, self.group)
        prog = compile_op(op, self.algo, **self.algo_kwargs)
        prog = apply_permutation(prog, self.perm)
        if self.chunks > 1:
            prog = chunk_pass(prog, self.chunks)
        return prog

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["group"] = list(self.group)
        d["perm"] = list(self.perm)
        return d

    @staticmethod
    def from_dict(d: dict) -> "PlanEntry":
        return PlanEntry(
            op=d["op"], bucket=int(d["bucket"]),
            size_bytes=float(d["size_bytes"]),
            group=tuple(int(x) for x in d["group"]),
            algo=d["algo"],
            algo_kwargs={k: int(v) for k, v in d["algo_kwargs"].items()},
            chunks=int(d["chunks"]),
            perm=tuple(int(x) for x in d["perm"]),
            expected_time=float(d["expected_time"]),
            identity_times={k: float(v) for k, v in d["identity_times"].items()},
            solver_cost=float(d["solver_cost"]),
            oracle=d["oracle"],
            program_fingerprint=d.get("program_fingerprint", ""),
            bucket_bytes=float(d.get("bucket_bytes", 0.0)),
        )


EntryKey = Tuple[str, int, Tuple[int, ...]]


@dataclasses.dataclass
class Plan:
    """A compiled collective plan for one fabric + one job mix."""

    fingerprint: "FabricFingerprint"          # see repro.plan.cache
    n: int
    entries: Dict[EntryKey, PlanEntry]
    mesh_plan: Optional[MeshPlan]
    compile_seconds: float
    mix_key: str
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    # -- queries ----------------------------------------------------------
    def _norm_group(self, group: Optional[Sequence[int]]) -> Tuple[int, ...]:
        if group is None:
            return tuple(range(self.n))
        return tuple(sorted(int(g) for g in group))

    def lookup(self, op: str, size_bytes: float,
               group: Optional[Sequence[int]] = None) -> Optional[PlanEntry]:
        """Entry for ``op`` at the nearest size bucket for ``group``."""
        g = self._norm_group(group)
        want = size_bucket(size_bytes)
        best, best_d = None, None
        for (eop, bucket, eg), entry in self.entries.items():
            if eop != op or eg != g:
                continue
            d = abs(bucket - want)
            if best_d is None or d < best_d:
                best, best_d = entry, d
        return best

    def total_time(self, mix: JobMix) -> float:
        """Oracle seconds for one pass over the mix under this plan."""
        total = 0.0
        for r in mix.requests:
            e = self.lookup(r.op, r.size_bytes, r.group)
            if e is not None:
                total += r.count * e.expected_time
        return total

    # -- serialization ----------------------------------------------------
    def to_json(self) -> str:
        from .cache import FabricFingerprint  # local: cache imports compiler

        assert isinstance(self.fingerprint, FabricFingerprint)
        d = {
            "version": 1,
            "fingerprint": self.fingerprint.to_dict(),
            "n": self.n,
            "entries": [e.to_dict() for e in self.entries.values()],
            "mesh_plan": None,
            "compile_seconds": self.compile_seconds,
            "mix_key": self.mix_key,
            "meta": self.meta,
        }
        if self.mesh_plan is not None:
            mp = self.mesh_plan
            d["mesh_plan"] = {
                "assignment": mp.assignment.tolist(),
                "axis_names": list(mp.axis_names),
                "cost": mp.cost,
                "baseline_cost": mp.baseline_cost,
                "per_axis": dict(mp.per_axis),
            }
        return json.dumps(d, indent=1)

    @staticmethod
    def from_json(s: str) -> "Plan":
        from .cache import FabricFingerprint

        d = json.loads(s)
        entries = {}
        for ed in d["entries"]:
            e = PlanEntry.from_dict(ed)
            entries[(e.op, e.bucket, e.group)] = e
        mesh_plan = None
        if d.get("mesh_plan"):
            mp = d["mesh_plan"]
            mesh_plan = MeshPlan(
                assignment=np.asarray(mp["assignment"], dtype=np.int64),
                axis_names=tuple(mp["axis_names"]),
                cost=float(mp["cost"]),
                baseline_cost=float(mp["baseline_cost"]),
                per_axis={k: float(v) for k, v in mp["per_axis"].items()},
            )
        return Plan(
            fingerprint=FabricFingerprint.from_dict(d["fingerprint"]),
            n=int(d["n"]),
            entries=entries,
            mesh_plan=mesh_plan,
            compile_seconds=float(d["compile_seconds"]),
            mix_key=d["mix_key"],
            meta=dict(d.get("meta", {})),
        )


@dataclasses.dataclass(frozen=True)
class SolveBudget:
    """Solver effort per entry; the service shares one compile across
    jobs, so a few seconds of compile buys every consumer."""

    iters: int = 800
    chains: int = 8
    chunk_candidates: Tuple[int, ...] = (1, 2, 4)
    #: don't bother chunking payloads below this (latency-bound regime)
    min_chunk_bytes: float = 64 * 1024
    #: forwarded to :func:`repro.core.solver.solve`
    engine: str = "vectorized"          # "vectorized" | "reference"
    backend: str = "numpy"              # "numpy" | "jax"
    #: groups at least this large solve by hierarchy decomposition
    #: (per-cluster then inter-cluster) when a recovered
    #: :class:`repro.fabric.HierarchyModel` is available — the flat SA
    #: search is the compile bottleneck at fleet scale
    hierarchy_min_n: int = 48
    #: candidate overlap-bucket payloads (bytes) scored per all-reduce
    #: entry; the octave's own size always joins as the single-bucket
    #: candidate
    bucket_candidates: Tuple[int, ...] = (1 << 18, 1 << 20, 1 << 22)


class PlanCompiler:
    """Compile a :class:`Plan` from a probe (or fabric) and a job mix.

    ``fabric``, when given, is the contention-aware oracle every
    candidate is validated against (offline: the synthetic "real cloud").
    Without it — live probing on hardware we cannot simulate — candidates
    are scored by their analytic cost model, which PR-0's Table-I
    reproduction showed rank-correlates with the simulator.
    """

    def __init__(self, fabric: Optional[Fabric] = None,
                 budget: Optional[SolveBudget] = None, seed: int = 0):
        self.fabric = fabric
        self.budget = budget or SolveBudget()
        self.seed = seed
        # static-verification verdicts, keyed by the program's schedule
        # *structure* (see _verify_key): size- and placement-invariant,
        # so one verify covers every bucket/group reusing the same
        # candidate — but rewrite passes that change the rounds
        # (chunking, fusion) get their own verdict
        self._verify_cache: Dict[Tuple, bool] = {}

    # -- static verification gate -----------------------------------------
    @staticmethod
    def _verify_key(program) -> Tuple:
        """Cache key of a program's structural verdict.

        The gate passes analyze rank space and never read ``perm``, so
        the verdict is placement- and payload-size-invariant — but it
        is NOT rewrite-invariant: ``chunk`` changes ``chunk_factor``
        and ``fuse_rounds`` changes the round structure, and replaying
        an unchunked/unfused verdict for the rewritten program would
        skip verifying what actually ships (the PR-8 key did exactly
        that).  The rewrite-pass signature ``(chunk_factor, number of
        rounds)`` distinguishes every rewrite the compiler applies
        today; anything more invasive changes the fingerprint-bearing
        rounds and should not share a verdict anyway.
        """
        return (program.algorithm, program.algo_kwargs, program.op.kind,
                program.n, program.chunk_factor, len(program.rounds))

    def _verify_gate(self, program, *, stage: str, cache: bool = True) -> None:
        """Hard gate: raise :class:`repro.analysis.VerificationError` on
        any error-level finding; warnings surface as obs events.

        ``GATE_PASSES`` includes the ``equiv`` translation validator,
        so passing the gate also certifies the program's ppermute
        lowering against its IR."""
        from repro.analysis import GATE_PASSES, require_valid

        key = self._verify_key(program)
        if cache and self._verify_cache.get(key):
            return
        report = require_valid(program, passes=GATE_PASSES)
        m = obs.metrics()
        m.counter("plan.verify.programs").inc()
        for f in report.by_severity("warning"):
            m.counter("plan.verify.warnings").inc()
            obs.tracer().event("plan.verify.warning", stage=stage,
                              algo=program.algorithm, code=f.code,
                              message=f.message)
        if cache:
            self._verify_cache[key] = True

    # -- inputs -----------------------------------------------------------
    @staticmethod
    def _matrices(probe) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """(lat, bw) from a ProbeResult, Fabric, or plain cost matrix."""
        if isinstance(probe, ProbeResult):
            return probe.lat, probe.bw
        if isinstance(probe, Fabric):
            return probe.lat, probe.bw
        c = np.asarray(probe, dtype=np.float64)
        assert c.ndim == 2 and c.shape[0] == c.shape[1]
        return c, None

    def _model(self, algo: str, lat, bw, size_bytes: float,
               akw: Dict[str, int]):
        """Cost model the solver optimizes the rank order with (the
        oracle executor then scores the *actual* program)."""
        m_algo = get_builder(algo).cost_model
        kwargs = {"base": akw["base"]} if "base" in akw else {}
        if bw is not None:
            return make_cost_model(m_algo, size_bytes=size_bytes,
                                   lat=lat, bw=bw, **kwargs)
        # paper mode: one latency-centric matrix, rounds rescale linearly
        return make_cost_model(m_algo, cost_matrix=lat,
                               size_bytes=size_bytes, **kwargs)

    # -- oracle -----------------------------------------------------------
    def _oracle(self, lat, bw):
        """The Executor candidates are scored on: the contention-aware
        simulator when a fabric is attached, the analytic cost-model
        math otherwise (live probing on hardware we cannot simulate)."""
        if self.fabric is not None:
            return SimExecutor(self.fabric)
        if bw is not None:
            return AnalyticExecutor(lat=lat, bw=bw)
        return AnalyticExecutor(cost_matrix=lat)

    # -- compilation ------------------------------------------------------
    def compile(self, probe, mix: JobMix,
                mesh_shape: Optional[Sequence[int]] = None,
                axis_names: Optional[Sequence[str]] = None,
                fingerprint=None,
                hierarchy: Optional[HierarchyModel] = None) -> Plan:
        """Compile the plan; ``hierarchy`` (or ``probe.hierarchy``, which
        a :class:`repro.fabric.SparseProbeResult` carries) switches large
        groups to hierarchy-decomposed solving and the fingerprint to the
        tree sketch."""
        # the obs timer is the one wall-clock source: always measures
        # (compile_seconds is a product number) and lands in the trace
        # whenever tracing is enabled
        timer = obs.tracer().timer("plan.compile", mix=mix.name)
        with timer:
            plan = self._compile_body(probe, mix, mesh_shape, axis_names,
                                        fingerprint, hierarchy)
            timer.set(entries=len(plan.entries))
        plan.compile_seconds = timer.elapsed
        m = obs.metrics()
        m.counter("plan.compiles").inc()
        m.histogram("plan.compile.seconds", scale=1e-3).observe(timer.elapsed)
        return plan

    def _compile_body(self, probe, mix: JobMix, mesh_shape, axis_names,
                        fingerprint, hierarchy) -> Plan:
        from .cache import fabric_fingerprint

        lat, bw = self._matrices(probe)
        n = lat.shape[0]
        if hierarchy is None:
            hierarchy = getattr(probe, "hierarchy", None)
        if hierarchy is not None and hierarchy.n != n:
            raise ValueError(
                f"hierarchy covers {hierarchy.n} nodes but the probe has "
                f"{n}; probe and hierarchy must describe the same fabric")
        if fingerprint is None:
            fingerprint = fabric_fingerprint(lat, bw, hierarchy=hierarchy)

        # Merge requests into (op, bucket, group) cells; the compile size
        # is the count-weighted geometric mean of the cell's sizes.
        cells: Dict[EntryKey, List[CollectiveRequest]] = {}
        for r in mix.requests:
            g = tuple(sorted(r.group)) if r.group is not None else tuple(range(n))
            if any(x < 0 or x >= n for x in g):
                raise ValueError(f"request group {g} outside fabric of {n} nodes")
            cells.setdefault((r.op, size_bucket(r.size_bytes), g), []).append(r)

        entries: Dict[EntryKey, PlanEntry] = {}
        for (op, bucket, group), reqs in sorted(cells.items()):
            w = np.asarray([r.count for r in reqs])
            s = np.asarray([r.size_bytes for r in reqs])
            repr_size = float(np.exp(np.average(np.log(np.maximum(s, 1.0)),
                                                weights=np.maximum(w, 1e-9))))
            with obs.tracer().span("plan.compile_entry", op=op,
                                   bucket=bucket, n=len(group)) as sp:
                entry = self._compile_entry(
                    op, bucket, group, repr_size, lat, bw, hierarchy)
                sp.set(algo=entry.algo, chunks=entry.chunks)
            entries[(op, bucket, group)] = entry

        mesh_plan = None
        if mesh_shape is not None:
            axis_names = tuple(axis_names or
                               ("pod", "data", "model")[-len(tuple(mesh_shape)):])
            # Mesh objective at the mix's dominant payload: lat + S/bw when
            # bandwidth was probed — multi-MB payloads are bw-dominated on
            # TPU fabrics (see topology.Fabric.cost_matrix).
            mesh_payload = max((r.size_bytes for r in mix.requests), default=0.0)
            c_mesh = lat.copy()
            if bw is not None and mesh_payload:
                with np.errstate(divide="ignore"):
                    c_mesh = c_mesh + mesh_payload / bw
            np.fill_diagonal(c_mesh, 0.0)
            c_mesh = np.maximum(c_mesh, c_mesh.T)
            mesh_plan = optimize_mesh_assignment(
                c_mesh, tuple(mesh_shape), axis_names, seed=self.seed,
                hierarchy=hierarchy)
            if mesh_plan.cost > mesh_plan.baseline_cost:
                # the heuristic can lose to identity on tiny/uniform
                # fabrics; a compiled plan must never ship a regression
                ident = np.arange(n, dtype=np.int64).reshape(tuple(mesh_shape))
                mesh_plan = MeshPlan(
                    assignment=ident, axis_names=axis_names,
                    cost=mesh_plan.baseline_cost,
                    baseline_cost=mesh_plan.baseline_cost,
                    per_axis={axis_names[a]: mesh_axis_cost(ident, c_mesh, a)
                              for a in range(len(axis_names))})

        return Plan(
            fingerprint=fingerprint,
            n=n,
            entries=entries,
            mesh_plan=mesh_plan,
            compile_seconds=0.0,        # stamped by compile()'s obs timer
            mix_key=mix.key(),
            meta={
                "mix_name": mix.name,
                "oracle": "simulator" if self.fabric is not None else "cost_model",
                "budget": dataclasses.asdict(self.budget),
                "hierarchy": hierarchy.to_dict() if hierarchy is not None
                             else None,
            },
        )

    def _compile_entry(self, op: str, bucket: int, group: Tuple[int, ...],
                       size_bytes: float, lat, bw,
                       hierarchy: Optional[HierarchyModel] = None) -> PlanEntry:
        g = np.asarray(group, dtype=np.int64)
        n_g = len(g)
        sub_lat = lat[np.ix_(g, g)]
        sub_bw = bw[np.ix_(g, g)] if bw is not None else None
        use_sim = self.fabric is not None
        oracle_name = "simulator" if use_sim else "cost_model"
        executor = self._oracle(lat, bw) if use_sim else None
        coll_op = CollectiveOp(kind_from_op(op), size_bytes, group)

        # Hierarchy decomposition: one locality-nested permutation per
        # entry (solve per cluster, then inter-cluster over supernodes)
        # replaces the per-algorithm flat SA search — the permutation is
        # pure locality nesting, so every candidate algorithm scores the
        # same one under its own cost model.
        hier_local: Optional[np.ndarray] = None
        if hierarchy is not None and not hierarchy.flat \
                and n_g >= self.budget.hierarchy_min_n:
            sub_h = hierarchy.restrict(group)
            if not sub_h.flat:
                hier_local = hierarchical_perm(
                    combine_cost(sub_lat, sub_bw, size_bytes), sub_h,
                    seed=self.seed)

        best = None          # (time, algo, akw, chunks, perm, mcost)
        identity_times: Dict[str, float] = {}
        identity_local = np.arange(n_g)
        # Chunking is scored as serial pieces, and the analytic cost
        # models are affine in payload — so without the contention-aware
        # simulator (whose fair-share rates are nonlinear) chunks > 1 is
        # mathematically dominated by chunks=1: skip the wasted oracles.
        chunk_cands = self.budget.chunk_candidates if use_sim else (1,)
        for algo, akw in candidate_algorithms(op, n_g):
            model = self._model(algo, sub_lat, sub_bw, size_bytes, akw)
            # Programs are only materialized when the oracle reads their
            # rounds (the simulator): the analytic oracle is the same
            # closed-form math as ``model`` at chunks=1, and building
            # every candidate's rounds just to discard them dominates
            # large-fleet compiles (bcube at n=1024 is ~1M flows).
            base_prog = compile_op(coll_op, algo, **akw) if use_sim else None
            if base_prog is not None:
                # gate every candidate the oracle will score; the verdict
                # is structural, so it caches across buckets and groups
                self._verify_gate(base_prog, stage="candidate")
            if hier_local is not None:
                solved_local = hier_local
            else:
                solved = solve(model, method="auto", iters=self.budget.iters,
                               chains=self.budget.chains, seed=self.seed,
                               engine=self.budget.engine,
                               backend=self.budget.backend)
                solved_local = np.asarray(solved.perm)
            for local in (identity_local, solved_local):
                node_perm = g[local]
                placed = apply_permutation(base_prog, node_perm) \
                    if use_sim else None
                for chunks in chunk_cands:
                    if chunks > 1 and size_bytes / chunks < self.budget.min_chunk_bytes:
                        continue
                    if use_sim:
                        t = executor.estimate(chunk_pass(placed, chunks))
                    else:
                        # == AnalyticExecutor.estimate on the candidate
                        # program (equivalence-tested), minus the rounds
                        t = float(model.cost(local))
                    if local is identity_local and chunks == 1:
                        identity_times[algo] = t
                    cand = (t, algo, akw, chunks, node_perm,
                            float(model.cost(local)))
                    if best is None or t < best[0]:
                        best = cand

        assert best is not None, f"no feasible algorithm for {op} over {n_g} nodes"
        t, algo, akw, chunks, node_perm, mcost = best
        winner = chunk_pass(
            apply_permutation(compile_op(coll_op, algo, **akw), node_perm),
            chunks)
        # the winner ships: verify it even in analytic mode (where no
        # candidate was gated).  The winner's key carries its rewrite
        # signature, so a chunked winner never reuses the unchunked
        # candidate verdict — it earns (and caches) its own
        self._verify_gate(winner, stage="winner")
        pos = {int(node): i for i, node in enumerate(g)}
        winner_local = np.asarray([pos[int(x)] for x in node_perm],
                                  dtype=np.int64)
        return PlanEntry(
            op=op, bucket=bucket, size_bytes=size_bytes, group=group,
            algo=algo, algo_kwargs=dict(akw), chunks=chunks,
            perm=tuple(int(x) for x in node_perm),
            expected_time=float(t), identity_times=identity_times,
            solver_cost=mcost, oracle=oracle_name,
            program_fingerprint=winner.fingerprint(),
            bucket_bytes=self._select_bucket_bytes(
                op, algo, akw, sub_lat, sub_bw, winner_local, size_bytes),
        )

    def _select_bucket_bytes(self, op: str, algo: str, akw: Dict[str, int],
                             sub_lat, sub_bw, local: np.ndarray,
                             size_bytes: float) -> float:
        """Overlap-bucket payload for this octave (all-reduce only).

        Scores each candidate bucket size ``b`` by the pipeline-makespan
        lower bound of running ``ceil(S / b)`` back-to-back schedules
        fused with compute: the first bucket's transfer is fully exposed
        (pipeline fill) and every later bucket still exposes its latency
        floor — the per-round issue cost that serializes with the
        applies even when bandwidth hides behind compute::

            score(b) = t(b) + (ceil(S / b) - 1) * t_latency_only

        Small buckets shrink the exposed fill but multiply the latency
        floor; large buckets amortize latency but leave a long fill.
        The winner's *analytic* model prices both terms — bucketing is a
        pipelining tradeoff, where the affine alpha-beta form suffices
        even when the entry itself was scored on the simulator (pricing
        ~4 extra programs per entry on the simulator would dominate
        compile time at fleet scale for no ranking change).
        """
        if op != "all-reduce" or size_bytes <= 0:
            return 0.0
        t_lat = float(self._model(algo, sub_lat, sub_bw, 0.0, akw)
                      .cost(local))
        cands = sorted(
            {float(b) for b in self.budget.bucket_candidates
             if 0 < b < size_bytes} | {float(size_bytes)},
            reverse=True)     # ties go to the larger bucket
        best_b, best_score = cands[0], None
        for b in cands:
            n_buckets = int(np.ceil(size_bytes / b))
            t_b = float(self._model(algo, sub_lat, sub_bw, b, akw)
                        .cost(local))
            score = t_b + (n_buckets - 1) * t_lat
            if best_score is None or score < best_score:
                best_b, best_score = b, score
        return best_b
