"""Thread-safe planning service: one compile, many consumers.

A production fleet has many jobs arriving concurrently, most of them on
the same fabric with the same handful of collective mixes.  Compiling is
seconds; serving a compiled plan must be microseconds.  The service
front-end therefore:

* checks the fingerprint-keyed :class:`~repro.plan.cache.PlanCache`
  first (warm path: an LRU dict probe);
* **deduplicates** concurrent misses — requests that agree on
  (fabric fingerprint, mix key, mesh shape) while a compile is already
  in flight join that compile's future instead of starting their own;
* runs compiles on a small worker pool so distinct fabrics/mixes compile
  concurrently;
* **batches** via :meth:`request_many`: requests sharing a fingerprint
  have their mixes unioned into one compile whose plan serves every
  caller (entries are keyed per (op, bucket, group), so a superset plan
  answers each sub-mix exactly).
"""

from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from repro import obs
from repro.faults.retry import RetryPolicy, call_with_retries

from .cache import PlanCache, fabric_fingerprint
from .compiler import JobMix, Plan, PlanCompiler

__all__ = ["PlanningService"]


def _mesh_suffix(mesh_shape, axis_names) -> str:
    if mesh_shape is None:
        return ""
    return f"|mesh={tuple(mesh_shape)}:{tuple(axis_names or ())}"


class PlanningService:
    """Concurrent front-end over a :class:`PlanCompiler` + :class:`PlanCache`."""

    def __init__(self, compiler: PlanCompiler,
                 cache: Optional[PlanCache] = None, max_workers: int = 2,
                 retry: Optional[RetryPolicy] = None):
        self.compiler = compiler
        self.cache = cache if cache is not None else PlanCache()
        #: when set, compiles transiently failing (a flaky probe feeding
        #: NaNs, a racing re-attach) are retried under capped backoff
        #: before the failure reaches the consumer's future
        self.retry = retry
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="repro-plan")
        self._lock = threading.Lock()
        self._inflight: Dict[Tuple[str, str], Future] = {}
        self._inflight_fp: Dict[Tuple[str, str], object] = {}
        self.stats = {"requests": 0, "cache_hits": 0,
                      "dedup_joins": 0, "compiles": 0}

    # -- single request ---------------------------------------------------
    def submit(self, probe, mix: JobMix,
               mesh_shape: Optional[Sequence[int]] = None,
               axis_names: Optional[Sequence[str]] = None) -> Future:
        """Plan future for (probe, mix); dedupes against in-flight work."""
        lat, bw = PlanCompiler._matrices(probe)
        fp = fabric_fingerprint(lat, bw,
                                hierarchy=getattr(probe, "hierarchy", None))
        request_key = mix.key() + _mesh_suffix(mesh_shape, axis_names)
        # The full lookup may scan the persistent store — keep that disk
        # I/O OUTSIDE the service lock (the cache locks itself) so
        # concurrent requests for distinct fabrics don't serialize.
        cached = self.cache.get(fp, request_key)
        with self._lock:
            self.stats["requests"] += 1
            obs.metrics().counter("plan.service.requests").inc()
            if cached is None:
                # a compile may have landed between the lookup and here
                cached = self.cache.peek_mem(fp, request_key)
            if cached is not None:
                self.stats["cache_hits"] += 1
                obs.metrics().counter("plan.service.cache_hits").inc()
                fut: Future = Future()
                fut.set_result(cached)
                return fut
            # join an in-flight compile whose fingerprint fuzzily matches
            for (digest, rk), fut in self._inflight.items():
                if rk != request_key:
                    continue
                in_fp = self._inflight_fp.get((digest, rk))
                if in_fp is not None and fp.matches(in_fp, self.cache.tol):
                    self.stats["dedup_joins"] += 1
                    obs.metrics().counter("plan.service.dedup_joins").inc()
                    return fut
            key = (fp.digest, request_key)
            fut = self._pool.submit(self._compile, key, fp, probe, mix,
                                    mesh_shape, axis_names, request_key)
            self._inflight[key] = fut
            self._inflight_fp[key] = fp
            return fut

    def request(self, probe, mix: JobMix,
                mesh_shape: Optional[Sequence[int]] = None,
                axis_names: Optional[Sequence[str]] = None) -> Plan:
        return self.submit(probe, mix, mesh_shape, axis_names).result()

    # -- batched requests -------------------------------------------------
    def request_many(
        self,
        requests: Sequence[Tuple[object, JobMix]],
    ) -> List[Plan]:
        """Serve several (probe, mix) requests, sharing compiles.

        Requests whose fabrics fingerprint-match are folded into ONE
        compile of the union mix; every caller receives that superset
        plan (lookups per (op, bucket, group) answer each sub-mix).
        """
        groups: List[Tuple[object, object, List[int], List[JobMix]]] = []
        for i, (probe, mix) in enumerate(requests):
            lat, bw = PlanCompiler._matrices(probe)
            fp = fabric_fingerprint(lat, bw,
                                    hierarchy=getattr(probe, "hierarchy", None))
            for g in groups:
                if fp.matches(g[1], self.cache.tol):
                    g[2].append(i)
                    g[3].append(mix)
                    break
            else:
                groups.append((probe, fp, [i], [mix]))

        out: List[Optional[Plan]] = [None] * len(requests)
        futures = []
        for probe, _fp, idxs, mixes in groups:
            union = JobMix(
                requests=tuple(r for m in mixes for r in m.requests),
                name="+".join(dict.fromkeys(m.name for m in mixes)),
            )
            futures.append((idxs, self.submit(probe, union)))
        for idxs, fut in futures:
            plan = fut.result()
            for i in idxs:
                out[i] = plan
        return out  # type: ignore[return-value]

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "PlanningService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- internals --------------------------------------------------------
    def _compile(self, key, fp, probe, mix, mesh_shape, axis_names,
                 request_key) -> Plan:
        try:
            def compile_once() -> Plan:
                return self.compiler.compile(
                    probe, mix, mesh_shape=mesh_shape, axis_names=axis_names,
                    fingerprint=fp)

            with obs.tracer().span("plan.service.compile", mix=mix.name):
                if self.retry is not None:
                    plan = call_with_retries(compile_once, self.retry)
                else:
                    plan = compile_once()
            with self._lock:
                self.stats["compiles"] += 1
                obs.metrics().counter("plan.service.compiles").inc()
            self.cache.put(plan, request_key)
            return plan
        finally:
            with self._lock:
                self._inflight.pop(key, None)
                self._inflight_fp.pop(key, None)
