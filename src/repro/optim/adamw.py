"""AdamW with global-norm clipping and ZeRO-1-shardable state.

Self-contained (no optax in the container).  State is a pytree mirroring
the parameters (``m``, ``v`` + a scalar count), so the sharding layer can
assign each moment tensor its own (ZeRO-1) spec independently of the
parameter spec.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt", "apply_opt", "global_norm",
           "cosine_schedule"]


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return fn


def init_opt(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_opt(
    cfg: AdamWConfig, params: Any, grads: Any, state: OptState
) -> Tuple[Any, OptState, dict]:
    """One AdamW step.  Returns (params', state', metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = state.count + 1
    lr = cfg.schedule(count) if cfg.schedule is not None else cfg.lr
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, OptState(new_m, new_v, count), metrics
