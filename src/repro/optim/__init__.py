from .adamw import (  # noqa: F401
    AdamWConfig,
    OptState,
    apply_opt,
    cosine_schedule,
    global_norm,
    init_opt,
)
from .compression import (  # noqa: F401
    compress_grads,
    compressed_psum,
    decompress_grads,
    error_feedback_update,
)
