"""Gradient compression with error feedback (distributed-optimization trick).

Int8 per-tensor quantization before the DP reduction, with a residual
(error-feedback) buffer so compression noise does not accumulate — the
standard 1-bit-Adam/PowerSGD-family recipe, here in its int8 form.

Two integration points:

* :func:`compress_grads` / :func:`decompress_grads` — value-level, usable
  inside any jit'd step (quantize -> sum in int32-widened form -> dequant).
* :func:`compressed_psum` — explicit shard_map collective for manual-DP
  code paths; sums int8 payloads in f32 after scaling (payload on the
  wire is the int8 tensor + one scalar scale).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_grads", "decompress_grads", "error_feedback_update",
           "compressed_psum"]


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def compress_grads(grads: Any, residual: Any) -> Tuple[Any, Any, Any]:
    """Quantize (grads + residual); returns (q8, scales, new_residual)."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, s = _quantize(g)
        deq = q.astype(jnp.float32) * s
        return q, s, g - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        tdef.unflatten([o[0] for o in out]),
        tdef.unflatten([o[1] for o in out]),
        tdef.unflatten([o[2] for o in out]),
    )


def decompress_grads(q8: Any, scales: Any) -> Any:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, q8, scales)


def error_feedback_update(grads: Any) -> Any:
    """Zero residuals matching a grad tree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """psum of an int8-quantized payload (inside shard_map).

    Wire bytes: 1/4 of f32 (int8 tensor) + one f32 scale.  The sum itself
    happens on the dequantized values — semantically a lossy psum.
    """
    q, s = _quantize(x.astype(jnp.float32))
    deq = q.astype(jnp.float32) * s
    return jax.lax.psum(deq, axis_name)
