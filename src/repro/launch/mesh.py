"""Production meshes (single-pod and multi-pod) with optional reordering.

``make_production_mesh()`` builds the assigned meshes:

* single-pod: ``(data=16, model=16)``  — 256 chips (TPU v5e-256 pod)
* multi-pod:  ``(pod=2, data=16, model=16)`` — 512 chips, ``pod`` on DCN

``make_reordered_mesh(plan)`` is the Cloud-Collectives integration point:
it permutes the device array with a solved :class:`MeshPlan` before
constructing the Mesh — the JAX equivalent of feeding the paper's
reordered IP list to an unmodified backend (DESIGN.md §2).

Defined as functions (never at import time) so importing this module
never touches JAX device state.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def production_shape(multi_pod: bool = False) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shape, axes


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape, axes = production_shape(multi_pod)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_reordered_mesh(plan, devices: Optional[Sequence] = None):
    """Mesh whose device order follows a solved rank plan (the paper)."""
    import jax
    from jax.sharding import Mesh

    if devices is None:
        devices = jax.devices()
    devices = np.asarray(devices, dtype=object)
    assert devices.size == plan.flat.size, (devices.size, plan.flat.size)
    arr = devices[plan.flat].reshape(plan.assignment.shape)
    return Mesh(arr, plan.axis_names)


def make_planned_mesh(plan, devices: Optional[Sequence] = None):
    """Mesh from a compiled :class:`repro.plan.Plan` (its N-D mesh plan).

    The plan side is the `repro.plan` subsystem's integration point: the
    planning service compiles (and caches, keyed by fabric fingerprint)
    the mesh assignment together with the per-collective entries, and
    this helper applies the assignment exactly like
    :func:`make_reordered_mesh` applies a bare :class:`MeshPlan`.
    """
    assert plan.mesh_plan is not None, \
        "plan was compiled without mesh_shape; request one from the service"
    return make_reordered_mesh(plan.mesh_plan, devices=devices)


def mesh_context(mesh):
    """Context manager activating ``mesh`` across jax versions.

    ``jax.set_mesh`` appeared in jax 0.5; older versions use the Mesh
    object itself as the context manager.
    """
    import jax

    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_mesh_for_tests(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Small mesh over however many devices the test process has."""
    import jax

    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
