"""Post-compile HLO introspection: collective bytes, FLOPs, roofline terms.

``cost_analysis()`` on XLA:CPU counts while-loop (= ``lax.scan``) bodies
ONCE, so scanned-layer models under-report by a factor of the trip count.
Two complementary tools deal with this:

* :func:`parse_collectives` — regex over the optimized HLO: sums result
  bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute, scaling ops inside while bodies by the loop trip
  count (extracted from the loop-condition constant, cross-checked
  against the model's known layer count).
* depth differencing (driver-level, see dryrun.py): lower the model at
  two unrolled depths and take the marginal per-layer cost at full width
  — HLO-grounded totals that sidestep loop accounting entirely.

Hardware model (TPU v5e targets from the assignment):
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "parse_collectives", "roofline_terms", "CollectiveStats"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link (per chip, ring)
    dcn_bw: float = 25e9 / 4          # bytes/s per chip across pods
    hbm_per_chip: float = 16e9        # v5e HBM capacity


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )

_COLL_NAME_RE = re.compile(
    r"\s(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _result_bytes(line: str) -> float:
    """Bytes of the op result: all shapes between '=' and the op name
    (a tuple result sums its element shapes)."""
    lhs = line.split("=", 1)
    if len(lhs) != 2:
        return 0.0
    m_op = _COLL_NAME_RE.search(lhs[1])
    head = lhs[1][: m_op.start()] if m_op else lhs[1].split("(", 1)[0]
    total = 0.0
    for m in _SHAPE_RE.finditer(head):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_type: Dict[str, float]
    count_by_type: Dict[str, int]
    total_bytes: float
    details: List[Tuple[str, str, float, int]]  # (comp, op, bytes, mult)


def _computations(hlo: str) -> Dict[str, List[str]]:
    """Split HLO text into computation blocks (name -> lines)."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$", line)
        m2 = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(", line)
        if (m or m2) and line.rstrip().endswith("{"):
            cur = (m or m2).group(1)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _while_multipliers(hlo: str, comps: Dict[str, List[str]],
                       default_trip: int = 1) -> Dict[str, int]:
    """comp name -> product of trip counts of enclosing while loops.

    Trip counts come from the largest integer constant in the loop's
    condition computation (standard counted-loop lowering).  Nested
    loops multiply.
    """
    # find while ops: body=%name, condition=%name
    body_of: Dict[str, Tuple[str, str]] = {}  # body comp -> (cond comp, parent comp)
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line:
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                if mb and mc:
                    body_of[mb.group(1)] = (mc.group(1), cname)

    def trip(cond_name: str) -> int:
        best = default_trip
        for line in comps.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
        return best

    # call graph: comp -> comps it calls (fusion/call/to_apply/body refs)
    calls: Dict[str, List[str]] = {c: [] for c in comps}
    ref_re = re.compile(
        r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)%?([\w\.\-]+)")
    for cname, lines in comps.items():
        for line in lines:
            for m in ref_re.finditer(line):
                if m.group(1) in comps:
                    calls[cname].append(m.group(1))

    mult: Dict[str, int] = {}

    def walk(c: str, factor: int, seen: frozenset) -> None:
        if c in seen:
            return
        mult[c] = max(mult.get(c, 0), factor)
        for child in calls.get(c, []):
            f = factor
            if child in body_of:
                f *= trip(body_of[child][0])
            walk(child, f, seen | {c})

    roots = [c for c in comps if "entry" in c.lower() or c.startswith("main")]
    if not roots:
        roots = list(comps)[:1]
    for r in roots:
        walk(r, 1, frozenset())
    # computations never reached from entry (conservative): factor 1
    for c in comps:
        mult.setdefault(c, 1)
    return mult


def parse_collectives(hlo: str, scale_loops: bool = True) -> CollectiveStats:
    comps = _computations(hlo)
    mults = _while_multipliers(hlo, comps) if scale_loops else {}
    bytes_by: Dict[str, float] = {}
    count_by: Dict[str, int] = {}
    details: List[Tuple[str, str, float, int]] = []
    for cname, lines in comps.items():
        factor = mults.get(cname, 1) if scale_loops else 1
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            if "-done" in line.split("=", 1)[-1][:40]:
                continue  # async done ops repeat the start's result
            op = m.group(1)
            b = _result_bytes(line) * factor
            bytes_by[op] = bytes_by.get(op, 0.0) + b
            count_by[op] = count_by.get(op, 0) + factor
            details.append((cname, op, b, factor))
    return CollectiveStats(
        bytes_by_type=bytes_by,
        count_by_type=count_by,
        total_bytes=sum(bytes_by.values()),
        details=details,
    )


def roofline_terms(
    total_flops: float,
    total_hbm_bytes: float,
    total_collective_bytes: float,
    n_chips: int,
    hw: HW = HW(),
    dcn_collective_bytes: float = 0.0,
) -> Dict[str, float]:
    """The three roofline terms (seconds) per the assignment formulas."""
    compute_s = total_flops / (n_chips * hw.peak_flops)
    memory_s = total_hbm_bytes / (n_chips * hw.hbm_bw)
    ici_bytes = total_collective_bytes - dcn_collective_bytes
    collective_s = (ici_bytes / (n_chips * hw.ici_bw)
                    + dcn_collective_bytes / (n_chips * hw.dcn_bw))
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda t: t[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": max(compute_s, memory_s, collective_s),
    }
