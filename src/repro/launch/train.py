"""Production training launcher.

On a real fleet::

    python -m repro.launch.train --arch glm4-9b --steps 1000 \
        --mesh 16x16 --reorder probe        # probe + solve + reordered mesh

On this CPU container it runs the same code path at smoke scale with a
simulated fleet (``--reorder simulate``), which is also what the CI-style
tests exercise.  The paper's technique enters exactly once: the device
order used to build the Mesh.
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = ("pod", "data", "model")[-len(dims):] if len(dims) == 3 else (
        ("data", "model") if len(dims) == 2 else ("data",))
    return dims, axes


def build_mesh(args, n_devices: int):
    """Mesh per --reorder policy: none | simulate | probe."""
    import jax

    from repro.core import (
        cost_matrix,
        make_tpu_fleet,
        optimize_mesh_assignment,
        probe_fabric,
        probe_mesh_pairwise,
        scramble,
    )
    from repro.launch.mesh import make_mesh_for_tests, make_reordered_mesh

    shape, axes = parse_mesh(args.mesh)
    if args.reorder == "none" or int(np.prod(shape)) != n_devices:
        return make_mesh_for_tests(shape, axes), None
    if args.reorder == "probe":
        probed = probe_mesh_pairwise()             # live-device probes
        c = cost_matrix(probed, args.payload_bytes)
    else:                                           # simulate
        pods = shape[0] if len(shape) == 3 else 1
        fleet, _ = scramble(
            make_tpu_fleet(n_pods=max(pods, 1),
                           pod_shape=(shape[-2], shape[-1])), seed=0)
        c = cost_matrix(probe_fabric(fleet), args.payload_bytes)
    plan = optimize_mesh_assignment(c, shape, axes)
    print(f"[launch] mesh plan: identity {plan.baseline_cost:.5f} -> "
          f"optimized {plan.cost:.5f} "
          f"({plan.baseline_cost / max(plan.cost, 1e-30):.2f}x)")
    return make_reordered_mesh(plan), plan


def main() -> None:
    import jax

    from repro.configs import get_config
    from repro.data import SyntheticLM, host_batch
    from repro.models import get_model
    from repro.optim import AdamWConfig, cosine_schedule
    from repro.train import Trainer, TrainerConfig, init_state, make_train_step

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--reorder", choices=["none", "simulate", "probe"],
                    default="simulate")
    ap.add_argument("--payload-bytes", type=float, default=4e6)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (CPU); drop on a real fleet")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg.smoke(), vocab_size=2048)
    model = get_model(cfg)
    mesh, plan = build_mesh(args, len(jax.devices()))

    state = init_state(model, jax.random.PRNGKey(0))
    opt = AdamWConfig(schedule=cosine_schedule(args.lr, 10, args.steps))
    step_fn = jax.jit(make_train_step(model, opt))
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)

    def batches():
        i = 0
        while True:
            yield host_batch(ds, i)
            i += 1

    with jax.set_mesh(mesh):
        trainer = Trainer(
            step_fn=step_fn, state=state, batches=batches(),
            cfg=TrainerConfig(total_steps=args.steps, ckpt_every=50,
                              ckpt_dir=args.ckpt_dir, log_every=20))
        report = trainer.run()
    h = report["history"]
    print(f"[launch] arch={cfg.name} steps={report['final_step']} "
          f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
