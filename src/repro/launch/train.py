"""Production training launcher internals.

The user-facing entry point is::

    python -m repro train --arch glm4-9b --steps 1000 \
        --mesh 16x16 --reorder probe        # probe + solve + reordered mesh

(``python -m repro.launch.train`` remains as a deprecation shim that
delegates there.)  :func:`build_mesh` is the piece the CLI and tests
share: it drives a :class:`repro.session.Session` through
probe → plan → apply and returns the (reordered) mesh plus the compiled
plan.  The paper's technique enters exactly once: the device order used
to build the Mesh.
"""

from __future__ import annotations

import warnings

import numpy as np


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = ("pod", "data", "model")[-len(dims):] if len(dims) == 3 else (
        ("data", "model") if len(dims) == 2 else ("data",))
    return dims, axes


def default_job_mix(payload_bytes: float, moe: bool = False):
    """Deprecated: use :func:`repro.session.train_mix`."""
    warnings.warn(
        "repro.launch.train.default_job_mix is deprecated; use "
        "repro.session.train_mix", DeprecationWarning, stacklevel=2)
    from repro.session import train_mix

    return train_mix(payload_bytes, moe=moe)


def build_mesh(args, n_devices: int, mix=None, moe: bool = False,
               session_config=None):
    """Mesh per --reorder policy: none | simulate | probe.

    ``simulate``/``probe`` run the full Session lifecycle: attach (a
    simulated scrambled TPU fleet, or live pairwise probes), plan (the
    per-collective algorithm + rank order + the N-D mesh assignment,
    compiled once and cached under the fabric fingerprint), apply (the
    reordered Mesh).  ``mix`` overrides the planned collective histogram
    (serving passes its decode-shaped mix); ``session_config`` supplies
    cache dir / budget / payload when the caller (the CLI) already
    resolved a :class:`~repro.session.SessionConfig`.

    Returns ``(mesh, plan)`` where plan is a :class:`repro.plan.Plan`
    (or None when reordering is off).
    """
    from repro.launch.mesh import make_mesh_for_tests
    from repro.session import Session, SessionConfig

    shape, axes = parse_mesh(args.mesh)
    if args.reorder == "none" or int(np.prod(shape)) != n_devices:
        return make_mesh_for_tests(shape, axes), None

    from repro.session.config import FabricConfig

    base = session_config or SessionConfig()
    pods = shape[0] if len(shape) == 3 else 1
    if args.reorder == "probe":
        fabric = {"kind": "live"}
    elif base.fabric != FabricConfig():
        fabric = {}          # the user declared a fabric: honor it
    else:                                           # simulate
        fabric = {"kind": "tpu-fleet", "n_pods": max(pods, 1),
                  "pod_shape": (shape[-2], shape[-1]) if len(shape) >= 2
                  else (shape[-1], 1),
                  "scramble_seed": 0}
    cache_dir = getattr(args, "plan_cache_dir", None)
    payload = getattr(args, "payload_bytes", None)
    cfg = base.replace(
        fabric=fabric,
        mesh={"shape": shape, "axis_names": axes},
        cache={"dir": cache_dir if cache_dir is not None
               else base.cache.dir},
        payload_bytes=payload if payload is not None else base.payload_bytes,
        moe=moe or base.moe,
    )
    with Session(cfg) as session:
        plan = session.plan(mix=mix)
        applied = session.apply()
        hit = "cache hit" if session.service.stats["cache_hits"] else \
            f"compiled in {plan.compile_seconds:.2f}s"
    mp = plan.mesh_plan
    print(f"[launch] plan {plan.fingerprint.digest} ({hit}): "
          f"mesh identity {mp.baseline_cost:.5f} -> optimized {mp.cost:.5f} "
          f"({mp.baseline_cost / max(mp.cost, 1e-30):.2f}x), "
          f"{len(plan.entries)} collective entries")
    mesh = applied.mesh
    if mesh is None:
        warnings.warn(
            "planned mesh could not be built; training on an "
            "UNREORDERED mesh (see the session warning above)",
            RuntimeWarning, stacklevel=2)
        mesh = make_mesh_for_tests(shape, axes)
    return mesh, plan


def main() -> None:
    """Deprecated entry point: delegates to ``python -m repro train``."""
    import sys

    warnings.warn(
        "python -m repro.launch.train is deprecated; use "
        "`python -m repro train`", DeprecationWarning, stacklevel=2)
    from repro.cli import main as cli_main

    raise SystemExit(cli_main(["train", *sys.argv[1:]]))


if __name__ == "__main__":
    main()
