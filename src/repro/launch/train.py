"""Production training launcher.

On a real fleet::

    python -m repro.launch.train --arch glm4-9b --steps 1000 \
        --mesh 16x16 --reorder probe        # probe + solve + reordered mesh

On this CPU container it runs the same code path at smoke scale with a
simulated fleet (``--reorder simulate``), which is also what the CI-style
tests exercise.  The paper's technique enters exactly once: the device
order used to build the Mesh.
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = ("pod", "data", "model")[-len(dims):] if len(dims) == 3 else (
        ("data", "model") if len(dims) == 2 else ("data",))
    return dims, axes


def default_job_mix(payload_bytes: float, moe: bool = False):
    """The collective histogram of a training step at ``payload_bytes``
    gradients: the per-step DP reduction plus the per-layer TP pair, and
    the EP all-to-all when the arch routes experts."""
    from repro.plan import CollectiveRequest, JobMix

    reqs = [
        CollectiveRequest("all-reduce", payload_bytes),           # gradients
        CollectiveRequest("all-gather", payload_bytes / 8, count=2.0),
        CollectiveRequest("reduce-scatter", payload_bytes / 8, count=2.0),
    ]
    if moe:
        reqs.append(CollectiveRequest("all-to-all", payload_bytes / 16,
                                      count=2.0))
    return JobMix(requests=tuple(reqs), name="train")


def build_mesh(args, n_devices: int, mix=None, moe: bool = False):
    """Mesh per --reorder policy: none | simulate | probe.

    ``simulate``/``probe`` go through the :mod:`repro.plan` service: the
    plan (per-collective algorithm + rank order + the N-D mesh
    assignment) is compiled once and cached under the fabric
    fingerprint, so relaunches — and other jobs on the same fabric —
    skip the solve entirely.  ``mix`` overrides the planned collective
    histogram (serving passes its decode-shaped mix); the default is
    :func:`default_job_mix` with ``moe`` adding the EP all-to-all.

    Returns ``(mesh, plan)`` where plan is a :class:`repro.plan.Plan`
    (or None when reordering is off).
    """
    from repro.core import (
        make_tpu_fleet,
        probe_fabric,
        probe_mesh_pairwise,
        scramble,
    )
    from repro.launch.mesh import make_mesh_for_tests, make_planned_mesh
    from repro.plan import PlanCache, PlanCompiler, PlanningService

    shape, axes = parse_mesh(args.mesh)
    if args.reorder == "none" or int(np.prod(shape)) != n_devices:
        return make_mesh_for_tests(shape, axes), None
    fleet = None
    if args.reorder == "probe":
        probed = probe_mesh_pairwise()             # live-device probes
    else:                                           # simulate
        pods = shape[0] if len(shape) == 3 else 1
        fleet, _ = scramble(
            make_tpu_fleet(n_pods=max(pods, 1),
                           pod_shape=(shape[-2], shape[-1])), seed=0)
        probed = probe_fabric(fleet)
    service = PlanningService(
        PlanCompiler(fabric=fleet),
        PlanCache(store_dir=getattr(args, "plan_cache_dir", None)))
    try:
        plan = service.request(
            probed, mix or default_job_mix(args.payload_bytes, moe=moe),
            mesh_shape=shape, axis_names=axes)
    finally:
        service.close()
    mp = plan.mesh_plan
    hit = "cache hit" if service.stats["cache_hits"] else \
        f"compiled in {plan.compile_seconds:.2f}s"
    print(f"[launch] plan {plan.fingerprint.digest} ({hit}): "
          f"mesh identity {mp.baseline_cost:.5f} -> optimized {mp.cost:.5f} "
          f"({mp.baseline_cost / max(mp.cost, 1e-30):.2f}x), "
          f"{len(plan.entries)} collective entries")
    return make_planned_mesh(plan), plan


def main() -> None:
    import jax

    from repro.configs import get_config
    from repro.data import SyntheticLM, host_batch
    from repro.models import get_model
    from repro.optim import AdamWConfig, cosine_schedule
    from repro.train import Trainer, TrainerConfig, init_state, make_train_step

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--reorder", choices=["none", "simulate", "probe"],
                    default="simulate")
    ap.add_argument("--payload-bytes", type=float, default=4e6)
    ap.add_argument("--plan-cache-dir", default=None,
                    help="persist compiled collective plans across launches")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (CPU); drop on a real fleet")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg.smoke(), vocab_size=2048)
    model = get_model(cfg)
    mesh, plan = build_mesh(args, len(jax.devices()),
                            moe=bool(cfg.n_experts))
    from repro.launch.specs import configure_sp
    configure_sp(cfg, mesh, plan=plan)   # SP/EP contexts + planned a2a ring

    state = init_state(model, jax.random.PRNGKey(0))
    opt = AdamWConfig(schedule=cosine_schedule(args.lr, 10, args.steps))
    step_fn = jax.jit(make_train_step(model, opt))
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)

    def batches():
        i = 0
        while True:
            yield host_batch(ds, i)
            i += 1

    with jax.set_mesh(mesh):
        trainer = Trainer(
            step_fn=step_fn, state=state, batches=batches(),
            cfg=TrainerConfig(total_steps=args.steps, ckpt_every=50,
                              ckpt_dir=args.ckpt_dir, log_every=20))
        report = trainer.run()
    h = report["history"]
    print(f"[launch] arch={cfg.name} steps={report['final_step']} "
          f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
