"""Launchers and mesh builders.

New code should go through :class:`repro.session.Session` (or ``python
-m repro train/serve``); the modules here remain the mechanical layer
the session drives:

* :mod:`repro.launch.mesh` — production / reordered / planned meshes;
* :mod:`repro.launch.train`, :mod:`repro.launch.serve` — launcher
  internals (their ``python -m`` entry points are deprecated shims
  delegating to :mod:`repro.cli`);
* :mod:`repro.launch.hlo_analysis`, :mod:`repro.launch.specs`,
  :mod:`repro.launch.dryrun` — HLO collective accounting and dry-run
  lowering cells.

Submodules import lazily so ``import repro.launch`` never touches jax.
"""

from importlib import import_module

_SUBMODULES = ("dryrun", "hlo_analysis", "mesh", "serve", "specs", "train")

__all__ = list(_SUBMODULES)


def __getattr__(name: str):
    if name in _SUBMODULES:
        module = import_module(f"{__name__}.{name}")
        globals()[name] = module
        return module
    raise AttributeError(f"module 'repro.launch' has no attribute {name!r}")
