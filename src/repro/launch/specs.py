"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

``input_specs`` builds weak-type-correct, sharding-annotated stand-ins
for every input of the lowered step function — no device allocation, so
the 236B-parameter cells lower on a CPU host.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ModelConfig, ShapeSpec
from repro.models import get_model
from repro.optim import init_opt
from repro.parallel import sharding as shd
from repro.train.train_step import TrainState, state_pspecs

__all__ = ["input_specs", "step_callable"]


def _with_sharding(shapes: Any, pspecs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        shapes, pspecs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


def configure_sp(cfg: ModelConfig, mesh: Mesh, plan=None) -> None:
    """Arm sequence-parallel + expert-parallel contexts (trace-time).

    ``plan`` (a compiled :class:`repro.plan.Plan`, e.g. the one
    ``launch.train.build_mesh`` returns) is forwarded to ``arm_ep`` so
    the EP all-to-all follows the plan's solved shift-ring order.
    """
    from repro.models import layers as L
    from repro.parallel.moe_a2a import arm_ep, clear_ep

    sizes = shd.mesh_axis_sizes(mesh)
    if cfg.sequence_parallel and sizes.get("model", 1) > 1:
        L.set_sequence_parallel(shd.dp_axes(mesh), "model", sizes["model"])
    else:
        L.clear_sequence_parallel()
    if cfg.n_experts and sizes.get("data", 1) > 1:
        arm_ep(mesh, "data",
               "model" if sizes.get("model", 1) > 1 else None,
               plan=plan)
    else:
        clear_ep()


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Tuple[Any, ...]:
    """ShapeDtypeStructs (sharded) for the step function of this cell."""
    import numpy as np

    model = get_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    sizes = shd.mesh_axis_sizes(mesh)
    dp_names = shd.dp_axes(mesh)
    dp_total = int(np.prod([sizes[a] for a in dp_names])) if dp_names else 1
    # batch=1 decode (long_500k) cannot shard the batch dim
    dp = shd.batch_spec(mesh) if (dp_total and B % dp_total == 0) else P(None)
    tok2 = P(*dp, None)
    tok1 = P(*dp)

    def frontend_shapes() -> Dict[str, jax.ShapeDtypeStruct]:
        extra = {}
        if cfg.family == "vlm":
            extra["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.family == "encdec":
            extra["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_audio_ctx, cfg.d_model), jnp.dtype(cfg.dtype))
        return extra

    if shape.kind == "train":
        state_shapes = jax.eval_shape(
            lambda: TrainState(
                params=model.init(jax.random.PRNGKey(0)),
                opt=init_opt(model.init(jax.random.PRNGKey(0))),
                step=jnp.zeros((), jnp.int32),
            ))
        s_specs = state_pspecs(state_shapes, cfg, mesh)
        state_sds = _with_sharding(state_shapes, s_specs, mesh)
        batch_shapes: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        batch_shapes.update(frontend_shapes())
        b_specs = {
            k: P(*dp, *([None] * (v.ndim - 1))) for k, v in batch_shapes.items()
        }
        batch_sds = _with_sharding(batch_shapes, b_specs, mesh)
        return state_sds, batch_sds

    params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = shd.param_pspecs(params_shapes, cfg, mesh)
    params_sds = _with_sharding(params_shapes, p_specs, mesh)

    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tok_sds = _with_sharding(tokens, tok2, mesh)
        extra = frontend_shapes()
        if extra:
            fe = list(extra.values())[0]
            fe_sds = _with_sharding(fe, P(*dp, None, None), mesh)
            return params_sds, tok_sds, fe_sds
        return params_sds, tok_sds

    # decode: one new token against an S-long cache
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    c_specs = shd.cache_pspecs(cache_shapes, cfg, mesh)
    cache_sds = _with_sharding(cache_shapes, c_specs, mesh)
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sds = _with_sharding(tokens, tok1, mesh)
    return params_sds, tok_sds, cache_sds


def step_callable(cfg: ModelConfig, shape: ShapeSpec):
    """The function each cell lowers: train_step / prefill / serve_step."""
    from repro.optim import AdamWConfig
    from repro.train.train_step import make_train_step

    model = get_model(cfg)
    if shape.kind == "train":
        return make_train_step(model, AdamWConfig())
    if shape.kind == "prefill":
        if cfg.family in ("vlm", "encdec"):
            return lambda params, tokens, fe: model.prefill(params, tokens, fe)
        return lambda params, tokens: model.prefill(params, tokens)
    return lambda params, tokens, cache: model.decode_step(params, tokens, cache)
