"""Production serving launcher: batched generation on a (reordered) mesh.

    python -m repro.launch.serve --arch deepseek-v2-236b --mesh 16x16
    python -m repro.launch.serve --arch rwkv6-1.6b --batch 8 --max-new 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    from repro.configs import get_config
    from repro.launch.train import build_mesh
    from repro.models import get_model
    from repro.serve import GenerationConfig, GenerationEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--reorder", choices=["none", "simulate", "probe"],
                    default="simulate")
    ap.add_argument("--payload-bytes", type=float, default=1e6)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = get_model(cfg)
    mesh, _ = build_mesh(args, len(jax.devices()))

    params = model.init(jax.random.PRNGKey(0))
    fe = None
    if cfg.family == "vlm":
        fe = jnp.ones((args.batch, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        fe = jnp.ones((args.batch, cfg.n_audio_ctx, cfg.d_model), jnp.float32)

    prompts = [
        [(11 * i + j) % cfg.vocab_size for j in range(args.prompt_len)]
        for i in range(args.batch)
    ]
    with jax.set_mesh(mesh):
        eng = GenerationEngine(
            model, params,
            GenerationConfig(max_new_tokens=args.max_new, eos_token=-1))
        t0 = time.perf_counter()
        outs = eng.generate(prompts, frontend_embeds=fe)
        dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"[serve] arch={cfg.name} {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
