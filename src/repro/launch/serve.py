"""Production serving launcher: batched generation on a (reordered) mesh.

    python -m repro.launch.serve --arch deepseek-v2-236b --mesh 16x16
    python -m repro.launch.serve --arch rwkv6-1.6b --batch 8 --max-new 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def serve_job_mix(payload_bytes: float, moe: bool = False):
    """The decode path's collective histogram: per-layer TP all-gather /
    reduce-scatter dominate; a small all-reduce syncs sampling state; MoE
    archs add the EP all-to-all.  (No gradient all-reduce — that is the
    training mix.)"""
    from repro.plan import CollectiveRequest, JobMix

    reqs = [
        CollectiveRequest("all-gather", payload_bytes, count=2.0),
        CollectiveRequest("reduce-scatter", payload_bytes, count=2.0),
        CollectiveRequest("all-reduce", max(payload_bytes / 64, 1.0)),
    ]
    if moe:
        reqs.append(CollectiveRequest("all-to-all", payload_bytes, count=2.0))
    return JobMix(requests=tuple(reqs), name="serve")


def main() -> None:
    from repro.configs import get_config
    from repro.launch.specs import configure_sp
    from repro.launch.train import build_mesh
    from repro.models import get_model
    from repro.serve import GenerationConfig, GenerationEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--reorder", choices=["none", "simulate", "probe"],
                    default="simulate")
    ap.add_argument("--payload-bytes", type=float, default=1e6)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = get_model(cfg)
    mesh, plan = build_mesh(
        args, len(jax.devices()),
        mix=serve_job_mix(args.payload_bytes, moe=bool(cfg.n_experts)))
    configure_sp(cfg, mesh, plan=plan)   # SP/EP contexts + planned a2a ring

    params = model.init(jax.random.PRNGKey(0))
    fe = None
    if cfg.family == "vlm":
        fe = jnp.ones((args.batch, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        fe = jnp.ones((args.batch, cfg.n_audio_ctx, cfg.d_model), jnp.float32)

    prompts = [
        [(11 * i + j) % cfg.vocab_size for j in range(args.prompt_len)]
        for i in range(args.batch)
    ]
    with jax.set_mesh(mesh):
        eng = GenerationEngine(
            model, params,
            GenerationConfig(max_new_tokens=args.max_new, eos_token=-1),
            plan=plan)
        if plan is not None:
            print(f"[serve] plan {plan.fingerprint.digest} hints: "
                  f"{eng.collective_hints(args.payload_bytes)}")
        t0 = time.perf_counter()
        outs = eng.generate(prompts, frontend_embeds=fe)
        dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"[serve] arch={cfg.name} {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
