"""Production serving launcher — deprecated shim.

The serving entry point moved to the unified CLI::

    python -m repro serve --arch deepseek-v2-236b --mesh 16x16
    python -m repro serve --arch rwkv6-1.6b --batch 8 --max-new 64

``python -m repro.launch.serve`` still works (delegating there), and
:func:`serve_job_mix` remains as a deprecated alias of
:func:`repro.session.serve_mix`.
"""

from __future__ import annotations

import warnings


def serve_job_mix(payload_bytes: float, moe: bool = False):
    """Deprecated: use :func:`repro.session.serve_mix`."""
    warnings.warn(
        "repro.launch.serve.serve_job_mix is deprecated; use "
        "repro.session.serve_mix", DeprecationWarning, stacklevel=2)
    from repro.session import serve_mix

    return serve_mix(payload_bytes, moe=moe)


def main() -> None:
    """Deprecated entry point: delegates to ``python -m repro serve``."""
    import sys

    warnings.warn(
        "python -m repro.launch.serve is deprecated; use "
        "`python -m repro serve`", DeprecationWarning, stacklevel=2)
    from repro.cli import main as cli_main

    raise SystemExit(cli_main(["serve", *sys.argv[1:]]))


if __name__ == "__main__":
    main()
