import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (see DESIGN.md §5 and EXPERIMENTS.md §Dry-run).

For every (architecture x input shape) cell this driver:

1. builds the production mesh — ``(16, 16)`` single-pod or
   ``(2, 16, 16)`` multi-pod — with 512 placeholder host devices;
2. lowers + compiles the cell's step function (train_step / prefill /
   serve_step) with full-size ShapeDtypeStruct inputs and the sharding
   rules of :mod:`repro.parallel.sharding` — success proves the
   distribution config is coherent;
3. records ``compiled.memory_analysis()`` (fits-in-HBM evidence),
   ``compiled.cost_analysis()`` (raw), loop-scaled collective bytes
   (:mod:`repro.launch.hlo_analysis`), and — because XLA:CPU counts scan
   bodies once — **depth-differenced** FLOPs/bytes: the model is lowered
   unrolled at two reduced depths at full width, and the marginal
   per-layer cost extrapolates to full depth (``--no-diff`` to skip);
4. derives the three roofline terms and writes one JSON per cell under
   ``--out``.

Usage::

    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
    python -m repro.launch.dryrun --all                  # 16x16 + 2x16x16
    python -m repro.launch.dryrun --all --multi-pod-only
"""

import argparse
import dataclasses
import json
import traceback
from typing import Any, Dict, Optional

import numpy as np

from repro import obs


def _cfg_overrides(cfg, overrides: Dict[str, Any]):
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    do_diff: bool = True,
    overrides: Optional[Dict[str, Any]] = None,
    verbose: bool = True,
) -> Dict[str, Any]:
    import jax

    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.launch import hlo_analysis as ha
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs, step_callable

    cfg = _cfg_overrides(get_config(arch), overrides or {})
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
    }
    if not ok:
        rec.update(status="skip", reason=why)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name}: SKIP ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    from repro.launch.specs import configure_sp

    configure_sp(cfg, mesh)
    fn = step_callable(cfg, shape)
    specs = input_specs(cfg, shape, mesh)

    # donation mirrors production: train donates the state, decode the
    # cache — memory_analysis then reports realistic aliasing.
    donate = (0,) if shape.kind == "train" else (
        (2,) if shape.kind == "decode" else ())
    lower_t = obs.tracer().timer("dryrun.lower", arch=arch, shape=shape_name)
    compile_t = obs.tracer().timer("dryrun.compile", arch=arch,
                                   shape=shape_name)
    with jax.set_mesh(mesh):
        with lower_t:
            lowered = jax.jit(fn, donate_argnums=donate).lower(*specs)
        with compile_t:
            compiled = lowered.compile()
    t_lower, t_compile = lower_t.elapsed, compile_t.elapsed

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    # per-device steady-state estimate: args are aliased/donated for train
    live = (mem["argument_bytes"] + mem["output_bytes"]
            - mem["alias_bytes"] + mem["temp_bytes"])
    mem["live_bytes_per_device"] = int(live)
    mem["fits_16GB"] = bool(live < ha.HW().hbm_per_chip)

    ca = compiled.cost_analysis() or {}
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))

    coll = ha.parse_collectives(compiled.as_text())
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={rec['mesh']}")
        print(f"  memory_analysis: {ma}")
        print(f"  cost_analysis(raw): flops={raw_flops:.3e} "
              f"bytes={raw_bytes:.3e}")
        print(f"  collectives (loop-scaled): "
              f"{ {k: f'{v:.3e}' for k, v in coll.bytes_by_type.items()} } "
              f"total={coll.total_bytes:.3e} B")
        print(f"  lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"live/device={live/1e9:.2f} GB fits16GB={mem['fits_16GB']}")

    rec.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem,
        cost_analysis_raw={"flops": raw_flops, "bytes_accessed": raw_bytes},
        collectives={
            "bytes_by_type": coll.bytes_by_type,
            "count_by_type": coll.count_by_type,
            "total_bytes": coll.total_bytes,
        },
    )

    if do_diff:
        try:
            rec["per_device"] = _depth_diff(cfg, shape, mesh, verbose)
        except Exception as e:  # depth-diff is best-effort
            rec["per_device"] = {"error": f"{type(e).__name__}: {e}"}

    _finish_roofline(rec, cfg, shape, n_chips)
    return rec


def _depth_variant(cfg, n: int):
    """Reduced-depth, unrolled, full-width copy of the config.

    Unrolls every scan that hides FLOPs from ``cost_analysis`` (which
    counts loop bodies once): the layer scan, the blockwise-attention
    q-chunk map, and the chunked-CE scan.  These chunked paths are
    memory layouts, not extra math, so disabling them leaves FLOPs/bytes
    semantics intact while making them countable.
    """
    kw: Dict[str, Any] = {"use_scan": False, "attn_q_chunk": 0,
                          "loss_chunk_size": 0}
    if cfg.block_pattern:
        kw["n_layers"] = n * len(cfg.block_pattern)
    else:
        kw["n_layers"] = n + cfg.n_dense_layers
    if cfg.family == "encdec":
        kw["n_encoder_layers"] = n
    return dataclasses.replace(cfg, **kw)


def _diff_layers(cfg, n: int) -> int:
    """How many 'marginal units' a depth-n variant contains."""
    return n


def _full_units(cfg) -> int:
    if cfg.block_pattern:
        return cfg.n_layers // len(cfg.block_pattern)  # (R,R,A) groups
    return cfg.n_layers - cfg.n_dense_layers


def _depth_diff(cfg, shape, mesh, verbose: bool) -> Dict[str, float]:
    """HLO-grounded totals via per-layer marginal cost (module docstring)."""
    import jax

    from repro.launch import hlo_analysis as ha
    from repro.launch.specs import input_specs, step_callable

    from repro.launch.specs import configure_sp

    results = []
    for n in (1, 2):
        c = _depth_variant(cfg, n)
        configure_sp(c, mesh)
        fn = step_callable(c, shape)
        specs = input_specs(c, shape, mesh)
        with jax.set_mesh(mesh):
            lowered = jax.jit(fn).lower(*specs)
            compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        coll = ha.parse_collectives(compiled.as_text(), scale_loops=True)
        results.append({
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll.total_bytes),
        })
    u_full = _full_units(cfg)
    out = {}
    for key in ("flops", "bytes", "coll"):
        c1, c2 = results[0][key], results[1][key]
        marginal = max(c2 - c1, 0.0)
        out[key + "_total"] = c1 + marginal * (u_full - 1)
        out[key + "_marginal"] = marginal
    if verbose:
        print(f"  depth-diff: flops={out['flops_total']:.3e}/dev "
              f"bytes={out['bytes_total']:.3e}/dev "
              f"coll={out['coll_total']:.3e}/dev "
              f"(marginal flops {out['flops_marginal']:.3e} x {u_full} units)")
    return out


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D train / 2*N*D inference (N = active params)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def _finish_roofline(rec, cfg, shape, n_chips: int) -> None:
    from repro.launch import hlo_analysis as ha

    pd = rec.get("per_device") or {}
    if "flops_total" in pd:
        # depth-diff numbers are per-device (cost_analysis is per-partition
        # post-SPMD); totals = per-device x chips.  Collectives: take the
        # larger of the depth-diff estimate and the loop-scaled parse of
        # the *shipped* (scanned/chunked) binary — the chunked attention
        # path can emit more collective traffic than the unrolled depth
        # variant (per-chunk K/V re-gathers; see EXPERIMENTS.md §Perf).
        total_flops = pd["flops_total"] * n_chips
        total_bytes = pd["bytes_total"] * n_chips
        total_coll = max(pd["coll_total"],
                         rec["collectives"]["total_bytes"]) * n_chips
        src = "depth_diff"
    else:
        total_flops = rec["cost_analysis_raw"]["flops"] * n_chips
        total_bytes = rec["cost_analysis_raw"]["bytes_accessed"] * n_chips
        total_coll = rec["collectives"]["total_bytes"] * n_chips
        src = "scan_raw"
    mf = _model_flops(cfg, shape)
    terms = ha.roofline_terms(total_flops, total_bytes, total_coll, n_chips)
    rec["roofline"] = dict(
        terms,
        source=src,
        hlo_flops=total_flops,
        hlo_bytes=total_bytes,
        collective_bytes=total_coll,
        model_flops=mf,
        useful_flops_frac=(mf / total_flops) if total_flops else 0.0,
    )


def main() -> None:
    from repro.configs import ARCH_IDS, SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--no-diff", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf tuning)")
    ap.add_argument("--suffix", default=None,
                    help="artifact filename suffix (default: '_opt' iff "
                         "--override is set)")
    args = ap.parse_args()

    overrides = json.loads(args.override) if args.override else None
    os.makedirs(args.out, exist_ok=True)

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [True] if args.multi_pod_only else (
        [False, True] if args.all else [args.multi_pod])
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        try:
            rec = run_cell(a, s, multi_pod=mp, do_diff=not args.no_diff,
                           overrides=overrides)
        except Exception as e:
            failures += 1
            rec = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[dryrun] {a} x {s} mesh={'2x16x16' if mp else '16x16'} "
                  f"FAILED: {e}")
        tag = "mp" if mp else "sp"
        suffix = args.suffix if args.suffix is not None else (
            "_opt" if overrides else "")
        path = os.path.join(args.out, f"{a}_{s}_{tag}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=float)
    print(f"[dryrun] done; {failures} failures; artifacts in {args.out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
