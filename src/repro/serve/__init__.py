from .engine import GenerationConfig, GenerationEngine, make_serve_step  # noqa: F401
