"""Batched generation engine: prefill + decode with KV/state caches.

Wave-based continuous batching: requests with equal prompt length join a
prefill wave; decode then steps the whole wave until every slot finishes
(EOS or per-request max).  The decode step function is jitted once per
(batch, s_max) and reused across waves.

On a mesh, caches follow :func:`repro.parallel.sharding.cache_pspecs`
(batch over DP axes, heads over model); the engine code is identical on
1 chip and 512 — this is the ``serve_step`` that the decode-shape
dry-run cells lower.

Multi-length batching via left-pad masks is future work; waves require
equal prompt lengths (assert below).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

__all__ = ["GenerationConfig", "GenerationEngine", "make_serve_step"]


@dataclasses.dataclass
class GenerationConfig:
    max_new_tokens: int = 32
    eos_token: int = 0
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0


def make_serve_step(model) -> Callable:
    """The single-token decode step used by the dry-run decode cells."""

    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    return serve_step


class GenerationEngine:
    def __init__(self, model, params, gen_cfg: Optional[GenerationConfig] = None,
                 plan=None, session=None):
        self.model = model
        self.params = params
        self.cfg = gen_cfg or GenerationConfig()
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        #: armed by arm_overlap(): the planned, certified all-gather
        #: schedule fused with decode/prefill compute
        self._overlap: Optional[Dict[str, Any]] = None
        self._overlap_decode: Optional[Callable] = None
        self.stats: Dict[str, float] = {"prefill_tokens": 0, "decode_steps": 0}
        #: a repro.session.Session may own the plan lifecycle for the
        #: engine: its (lazily compiled) plan is adopted when no explicit
        #: plan is passed, and re-plans it performs (drift) are visible
        #: because collective_hints() re-reads session.planned
        self.session = session
        if plan is None and session is not None:
            plan = session.plan() if session.planned is None else session.planned
        #: compiled collective plan (repro.plan.Plan) for the serving mesh;
        #: the engine's TP collectives ride the mesh built from it, and
        #: per-op entries are surfaced for operators via collective_hints()
        self.plan = plan
        if plan is not None:
            self.stats["plan_fingerprint"] = plan.fingerprint.digest

    def collective_hints(self, payload_bytes: float = 1e6) -> Dict[str, Dict]:
        """Per-op plan entries the decode-path collectives map onto.

        TP decode issues all-gather / reduce-scatter per layer; MoE
        archs add the EP all-to-all.  Returns {op: entry summary} from
        the plan's nearest size buckets (empty without a plan).
        """
        if self.session is not None and self.session.planned is not None:
            self.plan = self.session.planned       # pick up drift re-plans
        if self.plan is None:
            return {}
        out: Dict[str, Dict] = {}
        for op in ("all-gather", "reduce-scatter", "all-to-all"):
            e = self.plan.lookup(op, payload_bytes)
            if e is not None:
                out[op] = {
                    "algo": e.algo, "chunks": e.chunks,
                    "expected_time": e.expected_time,
                    "speedup_vs_identity":
                        e.best_identity_time / max(e.expected_time, 1e-30),
                }
                if e.program_fingerprint:
                    out[op]["program"] = e.program_fingerprint
        return out

    def lowered_collective(self, op: str, payload_bytes: float = 1e6):
        """The plan's lowered schedule for ``op`` at ``payload_bytes``.

        Rebuilds the entry's typed :class:`~repro.collective.Program`
        and lowers it through :class:`repro.collective.JaxExecutor` —
        the engine pulls the ppermute ring/shift schedule from the plan
        instead of re-deriving it from ``(algo, perm)`` tuples.  Returns
        a :class:`repro.collective.Lowered` (ring links or a2a shift
        rounds in axis-index space), or ``None`` when the plan has no
        entry for ``op`` or the chosen algorithm has no static ppermute
        form (e.g. halving-doubling, which XLA runs natively).
        """
        if self.session is not None and self.session.planned is not None:
            self.plan = self.session.planned       # pick up drift re-plans
        if self.plan is None:
            return None
        entry = self.plan.lookup(op, payload_bytes)
        if entry is None:
            return None
        from repro.collective import JaxExecutor

        ex = JaxExecutor()
        prog = entry.program()
        return ex.lower(prog) if ex.can_lower(prog) else None

    def arm_overlap(self, mesh, axis: str, payload_bytes: float = 1e6,
                    interpret: bool = True):
        """Fuse the planned all-gather into decode/prefill compute.

        Looks up the plan's all-gather entry at ``payload_bytes``,
        lowers it, **certifies the exact schedule artifact**
        (:func:`repro.analysis.require_certified` — unlike
        :meth:`lowered_collective`, nothing uncertified escapes here),
        and rearms the wave loop: each decode step then issues the
        schedule's rounds via :func:`repro.kernels.overlap.run_overlapped`
        with the *next* token's decode as resident compute, and prefill
        overlaps the prompt-activation gather with cache growth.  The
        gathered payload is the step's activation block, so the
        schedule's allgather postcondition is checkable against it
        (``generate`` checks the first step of every wave).

        Returns the certified :class:`LoweredSchedule`.
        """
        from repro.analysis import require_certified
        from repro.collective import JaxExecutor

        if self.session is not None and self.session.planned is not None:
            self.plan = self.session.planned
        if self.plan is None:
            raise ValueError("arm_overlap() needs a plan (or session)")
        entry = self.plan.lookup("all-gather", payload_bytes)
        if entry is None:
            raise ValueError(
                f"plan has no all-gather entry near {payload_bytes:.0f} B")
        prog = entry.program()
        sched = JaxExecutor().lower_schedule(prog)
        require_certified(prog, sched)
        if mesh.shape[axis] != sched.n:
            raise ValueError(f"mesh axis {axis!r} has {mesh.shape[axis]} "
                             f"devices, schedule wants {sched.n}")
        self._overlap = {"mesh": mesh, "axis": axis, "schedule": sched,
                         "interpret": interpret}

        def step(params, cur, cache, payload):
            from repro.kernels.overlap import run_overlapped

            gathered, (dec,) = run_overlapped(
                payload, mesh, axis, sched,
                compute=[lambda: self.model.decode_step(params, cur, cache)],
                use_pallas_add=False, interpret=interpret)
            logits, new_cache = dec
            return logits, new_cache, gathered

        self._overlap_decode = jax.jit(step)
        self.stats["overlap_algo"] = sched.algorithm
        return sched

    def _ag_payload(self, logits: jnp.ndarray) -> jnp.ndarray:
        """Rank-major ``[n, D]`` all-gather input from an activation block.

        The step's logits block stands in for the TP activations the
        gather moves on a real mesh; padded so every rank's shard is a
        whole number of schedule pieces.
        """
        sched = self._overlap["schedule"]
        n, k = sched.n, max(1, sched.chunk_factor)
        flat = logits.reshape(-1)
        per = -(-flat.size // n)
        per = -(-per // k) * k
        return jnp.pad(flat, (0, n * per - flat.size)).reshape(n, per)

    def _check_gather(self, payload, gathered) -> None:
        """End-to-end postcondition of the wave's first overlapped gather."""
        from repro.kernels.schedule_runner import check_postcondition

        bad = check_postcondition(self._overlap["schedule"],
                                  np.asarray(payload), np.asarray(gathered))
        if bad:
            raise RuntimeError(
                "overlapped all-gather violated its postcondition: "
                + "; ".join(bad[:3]))
        obs.metrics().counter("serve.overlap.postcondition_ok").inc()

    def _sample(self, logits: jnp.ndarray, rng) -> jnp.ndarray:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / self.cfg.temperature).astype(jnp.int32)

    def generate(
        self,
        prompts: List[List[int]],
        frontend_embeds: Optional[jnp.ndarray] = None,
        max_new_tokens: Optional[int] = None,
    ) -> List[List[int]]:
        """One wave: equal-length prompts -> generated continuations."""
        lens = {len(p) for p in prompts}
        assert len(lens) == 1, f"wave needs equal prompt lengths, got {lens}"
        max_new = max_new_tokens or self.cfg.max_new_tokens
        B = len(prompts)
        tokens = jnp.asarray(prompts, dtype=jnp.int32)
        P = tokens.shape[1]

        with obs.tracer().span("serve.prefill", batch=B, prompt_len=P):
            logits, cache = self._prefill(self.params, tokens, frontend_embeds)
        self.stats["prefill_tokens"] += B * P
        # grow the cache to P + max_new slots; when armed, the planned
        # all-gather of the prompt activations rides along, with the
        # cache growth as its resident compute
        if self._overlap is not None:
            ov = self._overlap
            from repro.kernels.overlap import run_overlapped

            payload = self._ag_payload(logits)
            with obs.tracer().span("serve.overlap.prefill",
                                   bytes=float(payload.nbytes)):
                _, (cache,) = run_overlapped(
                    payload, ov["mesh"], ov["axis"], ov["schedule"],
                    compute=[lambda: _grow_cache(cache, P, P + max_new)],
                    use_pallas_add=False, interpret=ov["interpret"])
        else:
            cache = _grow_cache(cache, P, P + max_new)

        # TP decode issues an all-gather + reduce-scatter of the step's
        # activations per layer; the per-step logits block is the
        # observable proxy for that payload on a single-host run
        act_bytes = float(logits.size * logits.dtype.itemsize)
        rec = obs.recorder()
        rng = jax.random.PRNGKey(self.cfg.seed)
        out = np.zeros((B, max_new), dtype=np.int32)
        finished = np.zeros(B, dtype=bool)
        cur = self._sample(logits, rng)
        timer = obs.tracer().timer("serve.decode", batch=B)
        with timer:
            for t in range(max_new):
                out[:, t] = np.where(
                    finished, self.cfg.eos_token, np.asarray(cur))
                finished |= np.asarray(cur) == self.cfg.eos_token
                if finished.all():
                    break
                rng, sub = jax.random.split(rng)
                if self._overlap is not None:
                    # step t's planned all-gather (of step t's activation
                    # block) is on the wire while step t+1's decode runs
                    payload = self._ag_payload(logits)
                    logits, cache, gathered = self._overlap_decode(
                        self.params, cur, cache, payload)
                    if t == 0:
                        self._check_gather(payload, gathered)
                else:
                    logits, cache = self._decode(self.params, cur, cache)
                self.stats["decode_steps"] += 1
                rec.record("all-gather", act_bytes)
                rec.record("reduce-scatter", act_bytes)
                cur = self._sample(logits, sub)
            timer.set(steps=t + 1)
        obs.metrics().counter("serve.waves").inc()
        return [row[: _trim(row, self.cfg.eos_token)].tolist() for row in out]


def _trim(row: np.ndarray, eos: int) -> int:
    hits = np.nonzero(row == eos)[0]
    return int(hits[0]) if len(hits) else len(row)


#: cache keys that carry a sequence dimension, and where it sits
#: (negative index).  State caches (wkv, h, conv, *_sx) never grow.
_SEQ_DIM = {"k": -2, "v": -2, "ckv": -2, "k_rope": -2}


def _grow_cache(cache: Any, cur_len: int, new_len: int) -> Any:
    """Pad the sequence dim of prefill caches to decode headroom.

    Key-aware: only KV/latent buffers grow; recurrent states and the
    ring-buffer window caches of the hybrid arch pass through untouched.
    (Whisper cross-attn xk/xv are fixed to the audio context — untouched.)
    """
    if new_len <= cur_len:
        return cache

    def grow(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        if name not in _SEQ_DIM or not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return leaf
        d = leaf.ndim + _SEQ_DIM[name]
        if leaf.shape[d] != cur_len:   # ring-buffer (hybrid) or fixed ctx
            return leaf
        pad = [(0, 0)] * leaf.ndim
        pad[d] = (0, new_len - cur_len)
        return jnp.pad(leaf, pad)

    return jax.tree_util.tree_map_with_path(grow, cache)
