"""Seeded program mutator: the verifier's own test harness.

Mutation testing for the *verifier*: take a known-good Program, break
it in one small, realistic way, and check the analysis catches it.  The
four mutation kinds mirror the bugs schedule generators actually write:

* ``drop_instr``      — delete one flow (a lost relay hop);
* ``swap_src_dst``    — reverse one flow's direction;
* ``corrupt_chunk``   — replace one carried chunk id with another;
* ``duplicate_round`` — execute one round twice in a row.

A mutant counts as *caught* when verification reports any error or
warning — the ``Report.clean`` gate, strictly stronger than the
compile gate.  ``kill_rate`` is the acceptance metric: the checked-in
benchmark requires >= 0.95 over the full builder catalogue.

Since PR 9 the same harness also screens the *translation validator*:
``LOWERING_MUTATIONS`` break a correct :class:`LoweredSchedule` the
ways a lowering bug would (drop a permute step, flip a participation
mask bit, swap a reduce↔copy tag) and
:func:`lowering_kill_rate` checks :func:`repro.analysis.equiv.bisimulate`
rejects each one.

Mutants are built with ``dataclasses.replace`` on the frozen IR and
deliberately bypass re-validation (that is the point); determinism
comes from seeding ``random.Random`` per call, never global state.
"""

from __future__ import annotations

import dataclasses
import random
import zlib
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.collective.executors import LoweredSchedule, PermuteStep
from repro.collective.ir import FlowInstr, Program

from .verify import verify_program

__all__ = [
    "MUTATIONS",
    "mutants",
    "kill_rate",
    "LOWERING_MUTATIONS",
    "lowering_mutants",
    "lowering_kill_rate",
]


def _replace_rounds(program: Program,
                    rounds: List[List[FlowInstr]]) -> Program:
    return dataclasses.replace(
        program, rounds=tuple(tuple(rnd) for rnd in rounds))


def _flat_sites(program: Program) -> List[Tuple[int, int]]:
    """(round index, flow index) of every instruction."""
    return [(r, i) for r, rnd in enumerate(program.rounds)
            for i in range(len(rnd))]


def _mut_drop_instr(program: Program,
                    rng: random.Random) -> Optional[Program]:
    sites = _flat_sites(program)
    if not sites:
        return None
    r, i = rng.choice(sites)
    rounds = [list(rnd) for rnd in program.rounds]
    del rounds[r][i]
    return _replace_rounds(program, rounds)


def _mut_swap_src_dst(program: Program,
                      rng: random.Random) -> Optional[Program]:
    sites = _flat_sites(program)
    if not sites:
        return None
    r, i = rng.choice(sites)
    rounds = [list(rnd) for rnd in program.rounds]
    f = rounds[r][i]
    rounds[r][i] = dataclasses.replace(f, src=f.dst, dst=f.src)
    return _replace_rounds(program, rounds)


def _mut_corrupt_chunk(program: Program,
                       rng: random.Random) -> Optional[Program]:
    sites = [(r, i) for (r, i) in _flat_sites(program)
             if program.rounds[r][i].chunks]
    if not sites:
        return None
    r, i = rng.choice(sites)
    rounds = [list(rnd) for rnd in program.rounds]
    f = rounds[r][i]
    chunks = list(f.chunks)
    j = rng.randrange(len(chunks))
    if program.n_chunks > 1:
        # swap to a different valid id — the subtle in-range corruption
        chunks[j] = (chunks[j] + rng.randrange(1, program.n_chunks)) \
            % program.n_chunks
    else:
        chunks[j] = program.n_chunks  # only option: out-of-range id
    rounds[r][i] = dataclasses.replace(f, chunks=tuple(chunks))
    return _replace_rounds(program, rounds)


def _mut_duplicate_round(program: Program,
                         rng: random.Random) -> Optional[Program]:
    nonempty = [r for r, rnd in enumerate(program.rounds) if rnd]
    if not nonempty:
        return None
    r = rng.choice(nonempty)
    rounds = [list(rnd) for rnd in program.rounds]
    rounds.insert(r, list(rounds[r]))
    return _replace_rounds(program, rounds)


#: name -> mutator(program, rng) -> mutated Program or None (no site)
MUTATIONS: Dict[str, Callable[[Program, random.Random],
                              Optional[Program]]] = {
    "drop_instr": _mut_drop_instr,
    "swap_src_dst": _mut_swap_src_dst,
    "corrupt_chunk": _mut_corrupt_chunk,
    "duplicate_round": _mut_duplicate_round,
}


def mutants(program: Program, seed: int = 0,
            per_kind: int = 3,
            kinds: Optional[Iterable[str]] = None,
            ) -> List[Tuple[str, Program]]:
    """Deterministic mutant batch: ``per_kind`` of each mutation kind.

    Mutants identical to the original (or to an earlier mutant of the
    same kind) are dropped, so short programs yield fewer than
    ``per_kind``.
    """
    out: List[Tuple[str, Program]] = []
    for kind in (kinds if kinds is not None else MUTATIONS):
        mutator = MUTATIONS[kind]
        # PYTHONHASHSEED-independent: fingerprint is hex, kind is CRC'd
        rng = random.Random(seed * 0x9E3779B1
                            ^ int(program.fingerprint()[:8], 16)
                            ^ zlib.crc32(kind.encode()))
        seen = {program.fingerprint()}
        for _ in range(per_kind * 4):          # retry budget for dup draws
            if sum(1 for k, _ in out if k == kind) >= per_kind:
                break
            m = mutator(program, rng)
            if m is None:
                break
            fp = m.fingerprint()
            if fp in seen:
                continue
            seen.add(fp)
            out.append((kind, m))
    return out


def kill_rate(programs: Iterable[Program], seed: int = 0,
              per_kind: int = 3,
              ) -> Tuple[float, List[Tuple[str, str, str]]]:
    """Fraction of mutants caught (error OR warning) over ``programs``.

    Returns ``(rate, survivors)`` with survivors as
    ``(algorithm, mutation kind, fingerprint)`` triples for diagnosis.
    """
    n_total = 0
    survivors: List[Tuple[str, str, str]] = []
    for prog in programs:
        for kind, m in mutants(prog, seed=seed, per_kind=per_kind):
            n_total += 1
            report = verify_program(m, passes=("validate", "deps",
                                               "liveness"))
            if report.clean:
                survivors.append((prog.algorithm, kind, m.fingerprint()))
    if n_total == 0:
        return 1.0, []
    return 1.0 - len(survivors) / n_total, survivors


# ---------------------------------------------------------------------------
# lowering-level mutants: the translation validator's own screen
# ---------------------------------------------------------------------------

def _replace_step(schedule: LoweredSchedule, r: int, s: int,
                  step: Optional[PermuteStep]) -> LoweredSchedule:
    """Schedule with round r's step s replaced (or deleted when None)."""
    rounds = [list(rnd) for rnd in schedule.rounds]
    if step is None:
        del rounds[r][s]
    else:
        rounds[r][s] = step
    return dataclasses.replace(
        schedule, rounds=tuple(tuple(rnd) for rnd in rounds))


def _step_sites(schedule: LoweredSchedule) -> List[Tuple[int, int]]:
    """(round index, step index) of every PermuteStep."""
    return [(r, s) for r, rnd in enumerate(schedule.rounds)
            for s in range(len(rnd))]


def _lmut_drop_step(schedule: LoweredSchedule,
                    rng: random.Random) -> Optional[LoweredSchedule]:
    """Delete one collective-permute step (a lost shift)."""
    sites = _step_sites(schedule)
    if not sites:
        return None
    r, s = rng.choice(sites)
    return _replace_step(schedule, r, s, None)


def _lmut_flip_mask(schedule: LoweredSchedule,
                    rng: random.Random) -> Optional[LoweredSchedule]:
    """Clear one participation bit an executed link depends on."""
    sites = []
    for r, s in _step_sites(schedule):
        step = schedule.rounds[r][s]
        for src, dst in step.links:
            if step.send_mask[src] and step.recv_mask[dst]:
                sites.append((r, s, "send", src))
                sites.append((r, s, "recv", dst))
    if not sites:
        return None
    r, s, side, pos = rng.choice(sites)
    step = schedule.rounds[r][s]
    if side == "send":
        mask = list(step.send_mask)
        mask[pos] = False
        step = dataclasses.replace(step, send_mask=tuple(mask))
    else:
        mask = list(step.recv_mask)
        mask[pos] = False
        step = dataclasses.replace(step, recv_mask=tuple(mask))
    return _replace_step(schedule, r, s, step)


def _lmut_swap_tag(schedule: LoweredSchedule,
                   rng: random.Random) -> Optional[LoweredSchedule]:
    """Flip one step's reduce↔copy tag (accumulate vs overwrite)."""
    sites = _step_sites(schedule)
    if not sites:
        return None
    r, s = rng.choice(sites)
    step = schedule.rounds[r][s]
    flipped = "copy" if step.op == "reduce" else "reduce"
    return _replace_step(
        schedule, r, s, dataclasses.replace(step, op=flipped))


#: name -> mutator(schedule, rng) -> mutated schedule or None (no site)
LOWERING_MUTATIONS: Dict[str, Callable[[LoweredSchedule, random.Random],
                                       Optional[LoweredSchedule]]] = {
    "drop_step": _lmut_drop_step,
    "flip_mask": _lmut_flip_mask,
    "swap_tag": _lmut_swap_tag,
}


def lowering_mutants(program: Program, seed: int = 0,
                     per_kind: int = 3,
                     kinds: Optional[Iterable[str]] = None,
                     ) -> List[Tuple[str, LoweredSchedule]]:
    """Deterministic broken-lowering batch for ``program``.

    The program is lowered once with the real
    :class:`~repro.collective.executors.JaxExecutor` path and each
    mutant is one small corruption of that correct artifact — exactly
    the faults a lowering bug would introduce.
    """
    from repro.collective.executors import JaxExecutor

    schedule = JaxExecutor().lower_schedule(program)
    out: List[Tuple[str, LoweredSchedule]] = []
    for kind in (kinds if kinds is not None else LOWERING_MUTATIONS):
        mutator = LOWERING_MUTATIONS[kind]
        rng = random.Random(seed * 0x9E3779B1
                            ^ int(schedule.fingerprint()[:8], 16)
                            ^ zlib.crc32(kind.encode()))
        seen = {schedule.fingerprint()}
        for _ in range(per_kind * 4):          # retry budget for dup draws
            if sum(1 for k, _ in out if k == kind) >= per_kind:
                break
            m = mutator(schedule, rng)
            if m is None:
                break
            fp = m.fingerprint()
            if fp in seen:
                continue
            seen.add(fp)
            out.append((kind, m))
    return out


def lowering_kill_rate(programs: Iterable[Program], seed: int = 0,
                       per_kind: int = 3,
                       ) -> Tuple[float, List[Tuple[str, str, str]]]:
    """Fraction of broken lowerings ``equiv.bisimulate`` rejects.

    A mutant is killed only by an *error*-level finding — translation
    validation is a hard gate, so warnings don't count.  Returns
    ``(rate, survivors)`` with survivors as ``(algorithm, mutation
    kind, schedule fingerprint)`` triples.
    """
    from .equiv import bisimulate

    n_total = 0
    survivors: List[Tuple[str, str, str]] = []
    for prog in programs:
        for kind, m in lowering_mutants(prog, seed=seed, per_kind=per_kind):
            n_total += 1
            findings, _stats = bisimulate(prog, m)
            if not any(f.severity == "error" for f in findings):
                survivors.append((prog.algorithm, kind, m.fingerprint()))
    if n_total == 0:
        return 1.0, []
    return 1.0 - len(survivors) / n_total, survivors
