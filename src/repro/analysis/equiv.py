"""Translation validation: certify a LoweredSchedule against its IR.

The lowering in :mod:`repro.collective.executors` turns a validated
:class:`~repro.collective.ir.Program` into per-round
``collective-permute`` steps.  This pass *proves* — per artifact, not
per compiler — that the two describe the same collective, by symbolic
execution of the schedule in rank space with the same chunk→contributor
abstract domain :mod:`repro.analysis.liveness` interprets programs in,
then chunk-for-chunk bisimulation:

1. **Shape** — the schedule's placement, chunk metadata, pipelining
   factor, and round count must match the program's
   (``SCHEDULE_SHAPE``, error), and every step must be a well-formed
   partial permutation (``MALFORMED_STEP``, error).
2. **Per-round transfer multisets** — each IR round's ``(src rank,
   dst rank, chunk, op)`` multiset must equal the round's executed
   step transfers, where a link ``(s, d)`` executes iff
   ``send_mask[s] and recv_mask[d]``.  A schedule transfer the IR
   never asked for is ``EXTRA_TRANSFER``; a missing reduce is
   ``LOST_REDUCTION``; a missing or misrouted copy is
   ``MISMATCHED_DELIVERY`` (all errors).
3. **Final abstract state** — both sides are executed to completion
   under barrier semantics and the per-(rank, chunk) contributor sets
   must agree exactly (divergence is ``MISMATCHED_DELIVERY``).

:func:`bisimulate` is the core; the registered ``equiv`` pass lowers
the program itself (via ``JaxExecutor.lower_schedule``) and certifies
the pair, so adding ``equiv`` to ``GATE_PASSES`` makes every compile
gate a translation-validation gate.  :func:`certify_stages` re-proves
the lowering after each rewrite pass (``apply_permutation`` →
``chunk`` → ``fuse_rounds``) for pass-by-pass differential verdicts.

Verdicts are *rank-space*: the bisimulation is invariant under the
node-id permutation (``apply_permutation`` only relabels
``perm``/``order`` consistently), which is what makes the compiler's
placement-invariant verdict cache sound — but NOT invariant under
``chunk``/``fuse_rounds``, which is exactly why the cache key carries
the rewrite signature (see ``plan/compiler.py``).
"""

from __future__ import annotations

from collections import Counter
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.collective.executors import JaxExecutor, LoweredSchedule
from repro.collective.ir import INITS, Program, _initial_state

from .report import Finding, Report, VerificationError, finding

__all__ = [
    "PASS",
    "bisimulate",
    "symbolic_execute",
    "certify_stages",
    "require_certified",
]

PASS = "equiv"

#: abstract state: rank -> chunk id -> contributor rank set
State = Dict[int, Dict[int, FrozenSet[int]]]

#: rewrite stages :func:`certify_stages` proves, in application order
STAGES = ("base", "apply_permutation", "chunk", "fuse_rounds")


def _schedule_initial_state(schedule: LoweredSchedule) -> State:
    """The lowered artifact's declared init, in the liveness domain."""
    n = schedule.n
    full = frozenset(range(n))
    if schedule.init == "replicated":
        return {r: {c: frozenset((r,)) for c in range(schedule.n_chunks)}
                for r in range(n)}
    if schedule.init == "sharded":
        return {r: {r: full} for r in range(n)}
    if schedule.init == "addressed":
        return {s: {s * n + d: frozenset((s,)) for d in range(n)}
                for s in range(n)}
    raise ValueError(f"unknown init {schedule.init!r}; "
                     f"expected one of {INITS}")


def _check_steps(schedule: LoweredSchedule) -> List[Finding]:
    """Structural well-formedness of every PermuteStep."""
    findings: List[Finding] = []
    n = schedule.n
    for r_i, rnd in enumerate(schedule.rounds):
        for s_i, step in enumerate(rnd):
            if step.op not in ("reduce", "copy"):
                findings.append(finding(
                    PASS, "MALFORMED_STEP", "error",
                    f"round {r_i} step {s_i}: unknown op {step.op!r}",
                    round=r_i, step=s_i))
            if len(step.chunks) != len(step.links):
                findings.append(finding(
                    PASS, "MALFORMED_STEP", "error",
                    f"round {r_i} step {s_i}: {len(step.links)} links but "
                    f"{len(step.chunks)} chunk groups", round=r_i, step=s_i))
            if len(step.send_mask) != n or len(step.recv_mask) != n:
                findings.append(finding(
                    PASS, "MALFORMED_STEP", "error",
                    f"round {r_i} step {s_i}: masks sized "
                    f"{len(step.send_mask)}/{len(step.recv_mask)} for "
                    f"n={n}", round=r_i, step=s_i))
            srcs = [s for s, _ in step.links]
            dsts = [d for _, d in step.links]
            if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
                findings.append(finding(
                    PASS, "MALFORMED_STEP", "error",
                    f"round {r_i} step {s_i}: links {step.links} are not "
                    f"a partial permutation (duplicated endpoint)",
                    round=r_i, step=s_i))
            bad = [e for e in srcs + dsts if not 0 <= e < n]
            if bad:
                findings.append(finding(
                    PASS, "MALFORMED_STEP", "error",
                    f"round {r_i} step {s_i}: endpoint positions {bad} "
                    f"out of range for n={n}", round=r_i, step=s_i))
    return findings


def _round_transfers(
    schedule: LoweredSchedule, rnd, rank_of: Tuple[int, ...],
) -> Counter:
    """Executed ``(src rank, dst rank, chunk, op)`` multiset of a round.

    Honors the mask semantics: a link fires only when its source sends
    *and* its destination receives.
    """
    out: Counter = Counter()
    for step in rnd:
        for (s, d), chunks in zip(step.links, step.chunks):
            if not (0 <= s < schedule.n and 0 <= d < schedule.n):
                continue  # MALFORMED_STEP already filed
            if not (step.send_mask[s] and step.recv_mask[d]):
                continue
            for c in chunks:
                out[(rank_of[s], rank_of[d], c, step.op)] += 1
    return out


def symbolic_execute(schedule: LoweredSchedule) -> State:
    """Run the schedule in rank space under the liveness domain.

    Rounds are barriers: all steps of a round read round-entry state
    and receives are applied together at the round boundary — exactly
    the staging discipline ``repro.kernels.schedule_runner`` implements
    on devices.  A send of an unheld chunk contributes nothing (the
    divergence surfaces in the final-state comparison).
    """
    rank_of = schedule.rank_of
    state = _schedule_initial_state(schedule)
    for rnd in schedule.rounds:
        updates: List[Tuple[str, int, int, FrozenSet[int]]] = []
        for step in rnd:
            for (s, d), chunks in zip(step.links, step.chunks):
                if not (0 <= s < schedule.n and 0 <= d < schedule.n):
                    continue
                if not (step.send_mask[s] and step.recv_mask[d]):
                    continue
                src, dst = rank_of[s], rank_of[d]
                for c in chunks:
                    held = state[src].get(c)
                    if held is None:
                        continue
                    updates.append((step.op, dst, c, held))
        for op, dst, c, contribs in updates:
            if op == "reduce":
                state[dst][c] = state[dst].get(c, frozenset()) | contribs
            else:
                state[dst][c] = contribs
    return state


def _program_final_state(program: Program) -> State:
    """ir.validate's abstract execution, state returned not judged."""
    state = _initial_state(program)
    for rnd in program.rounds:
        updates: List[Tuple[str, int, int, FrozenSet[int]]] = []
        for f in rnd:
            src_chunks = state[f.src]
            for c in f.chunks:
                held = src_chunks.get(c)
                if held is None:
                    continue  # validate owns the unheld-send error
                updates.append((f.op, f.dst, c, held))
        for op, dst, c, contribs in updates:
            if op == "reduce":
                state[dst][c] = state[dst].get(c, frozenset()) | contribs
            else:
                state[dst][c] = contribs
    return state


def bisimulate(
    program: Program,
    schedule: Optional[LoweredSchedule] = None,
) -> Tuple[List[Finding], Dict[str, object]]:
    """Prove ``schedule`` equivalent to ``program`` chunk-for-chunk.

    With ``schedule=None`` the program is lowered first (the registered
    ``equiv`` pass form).  Returns liveness-style ``(findings, stats)``.
    """
    if schedule is None:
        schedule = JaxExecutor().lower_schedule(program)
    findings: List[Finding] = []
    n = program.n

    # -- 1. shape ---------------------------------------------------------
    lp = tuple(int(i) for i in program.local_perm)
    shape_errs = []
    if schedule.n != n:
        shape_errs.append(f"n {schedule.n} != {n}")
    if tuple(schedule.order) != lp:
        shape_errs.append(f"order {schedule.order} != local_perm {lp}")
    if schedule.n_chunks != program.n_chunks:
        shape_errs.append(
            f"n_chunks {schedule.n_chunks} != {program.n_chunks}")
    if abs(schedule.chunk_bytes - program.chunk_bytes) > 1e-9 * max(
            program.chunk_bytes, 1.0):
        shape_errs.append(
            f"chunk_bytes {schedule.chunk_bytes} != {program.chunk_bytes}")
    if schedule.chunk_factor != program.chunk_factor:
        shape_errs.append(
            f"chunk_factor {schedule.chunk_factor} != "
            f"{program.chunk_factor}")
    if schedule.init != program.init:
        shape_errs.append(f"init {schedule.init!r} != {program.init!r}")
    if schedule.postcondition != program.postcondition:
        shape_errs.append(
            f"postcondition {schedule.postcondition!r} != "
            f"{program.postcondition!r}")
    if len(schedule.rounds) != len(program.rounds):
        shape_errs.append(
            f"{len(schedule.rounds)} lowered rounds != "
            f"{len(program.rounds)} IR rounds")
    for err in shape_errs:
        findings.append(finding(
            PASS, "SCHEDULE_SHAPE", "error",
            f"lowered schedule disagrees with program shape: {err}"))
    findings.extend(_check_steps(schedule))
    if any(f.severity == "error" for f in findings):
        # round/state comparison against a misshapen schedule would
        # only pile secondary findings on the primary one
        return findings, {"bisimilar": False,
                          "schedule_fingerprint": schedule.fingerprint()}

    # -- 2. per-round transfer multisets ----------------------------------
    rank_of = schedule.rank_of
    n_transfers = 0
    for r_i, (p_rnd, s_rnd) in enumerate(
            zip(program.rounds, schedule.rounds)):
        want: Counter = Counter()
        for f in p_rnd:
            for c in f.chunks:
                want[(f.src, f.dst, c, f.op)] += 1
        got = _round_transfers(schedule, s_rnd, rank_of)
        n_transfers += sum(got.values())
        extra = got - want
        missing = want - got
        for (src, dst, c, op), k in sorted(extra.items()):
            findings.append(finding(
                PASS, "EXTRA_TRANSFER", "error",
                f"round {r_i}: schedule executes {op} of chunk {c} "
                f"{src}→{dst} ({k}x) the program never issues",
                round=r_i, src=src, dst=dst, chunk=c))
        for (src, dst, c, op), k in sorted(missing.items()):
            code = "LOST_REDUCTION" if op == "reduce" \
                else "MISMATCHED_DELIVERY"
            findings.append(finding(
                PASS, code, "error",
                f"round {r_i}: program {op} of chunk {c} {src}→{dst} "
                f"({k}x) is not executed by the lowered schedule",
                round=r_i, src=src, dst=dst, chunk=c))

    # -- 3. final abstract state ------------------------------------------
    want_state = _program_final_state(program)
    got_state = symbolic_execute(schedule)
    n_mismatched = 0
    for r in range(n):
        chunks = set(want_state.get(r, ())) | set(got_state.get(r, ()))
        for c in sorted(chunks):
            w = want_state.get(r, {}).get(c)
            g = got_state.get(r, {}).get(c)
            if w != g:
                n_mismatched += 1
                if n_mismatched <= 8:  # cap the flood; the count is in stats
                    findings.append(finding(
                        PASS, "MISMATCHED_DELIVERY", "error",
                        f"final state diverges at rank {r} chunk {c}: "
                        f"program holds contributors "
                        f"{sorted(w) if w else w}, schedule holds "
                        f"{sorted(g) if g else g}", dst=r, chunk=c))

    ok = not any(f.severity == "error" for f in findings)
    stats: Dict[str, object] = {
        "bisimilar": ok,
        "n_steps": schedule.n_steps,
        "n_transfers": n_transfers,
        "n_mismatched_entries": n_mismatched,
        "max_steps_per_round": max(
            (len(r) for r in schedule.rounds), default=0),
        "schedule_fingerprint": schedule.fingerprint(),
    }
    return findings, stats


def require_certified(program: Program,
                      schedule: Optional[LoweredSchedule] = None) -> Dict[
                          str, object]:
    """Bisimulate and raise :class:`VerificationError` on any error.

    The hard-gate form ``Session.lower`` calls on the exact artifact it
    hands to the runtime; returns the stats (which carry the certified
    ``schedule_fingerprint``) on success.
    """
    findings, stats = bisimulate(program, schedule)
    errors = [f for f in findings if f.severity == "error"]
    if errors:
        report = Report(algorithm=program.algorithm, kind=program.op.kind,
                        n=program.n,
                        program_fingerprint=program.fingerprint(),
                        findings=findings, stats={PASS: stats},
                        passes_run=[PASS])
        raise VerificationError(
            f"lowered schedule for {program.algorithm} (n={program.n}, "
            f"kind={program.op.kind}) failed translation validation with "
            f"{len(errors)} error(s): {errors[0].code} — "
            f"{errors[0].message}", report=report)
    return stats


def certify_stages(
    program: Program,
    perm: Optional[Tuple[int, ...]] = None,
    chunk_k: int = 1,
    fuse: bool = True,
) -> List[Dict[str, object]]:
    """Differential translation validation across the rewrite passes.

    Starting from ``program`` (stage ``base``), applies each rewrite in
    the compiler's order — ``apply_permutation(perm)``, ``chunk(k)``,
    ``fuse_rounds`` — re-lowering and re-bisimulating after every one.
    Returns one verdict dict per executed stage::

        {"stage", "ok", "n_findings", "codes", "stats",
         "program_fingerprint"}

    A lowering bug that only manifests after a particular rewrite
    (e.g. fusion changing the step packing) is pinned to its stage.
    Stages whose rewrite is a no-op (identity perm / k=1 / nothing to
    fuse) still certify — the proof is cheap and the matrix stays
    rectangular.
    """
    from repro.collective.passes import apply_permutation, chunk, fuse_rounds

    out: List[Dict[str, object]] = []
    current = program

    def run(stage: str, prog: Program) -> None:
        findings, stats = bisimulate(prog)
        out.append({
            "stage": stage,
            "ok": not any(f.severity == "error" for f in findings),
            "n_findings": len(findings),
            "codes": sorted({f.code for f in findings}),
            "stats": stats,
            "program_fingerprint": prog.fingerprint(),
        })

    run("base", current)
    if perm is not None:
        current = apply_permutation(current, perm)
        run("apply_permutation", current)
    if chunk_k > 1:
        current = chunk(current, chunk_k)
        run("chunk", current)
    if fuse:
        current, _ = fuse_rounds(current, verify=False)
        run("fuse_rounds", current)
    return out


def analyze_equiv(
    program: Program,
) -> Tuple[List[Finding], Dict[str, object]]:
    """The registered pass form: lower ``program`` and certify the pair."""
    return bisimulate(program)
