"""Optimality bounds: static cost vs the bandwidth lower bound.

For each collective kind there is a classic per-rank communication
lower bound (Chan et al., "Collective communication: theory, practice,
and experience"): with S total payload over n ranks, every allreduce
must move at least ``2(n-1)/n * S`` bytes through some rank's NIC, and
all-gather / reduce-scatter / all-to-all / rooted reduce at least
``(n-1)/n * S``.  The bound is keyed off the program's *postcondition*
— what it provably achieves — not the kind it registered under (bcube
registers as allreduce for cost-model parity but builds only the
reduce-scatter phase).

The program's statically derived cost uses the same single-port
full-duplex NIC model the bound assumes: a round costs the maximum over
ranks of bytes that rank sends (or receives, whichever is larger), and
rounds serialize.  The ratio ``lower_bound / static_cost`` is the
program's bandwidth efficiency:

* the chunked ring, halving-doubling, bcube, recursive-doubling and
  the shifted all-to-all all hit 1.0 exactly;
* the naive sequential ring lands at ``1/(2n)`` — the whole payload
  re-walks the ring twice with zero pipelining against its rooted
  ``reduce`` bound, which is precisely the paper's motivating regime;
* the latency side is reported alongside (executed rounds vs the
  ``ceil(log2 n)`` floor), not folded into one number.

Findings are info-level measurements: a low ratio is a property of the
chosen algorithm, not a bug in the program.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

from repro.collective.ir import Program

from .report import Finding, finding

__all__ = ["analyze_bounds", "bandwidth_lower_bound"]

PASS = "bounds"

#: per-rank wire-byte factors of S, by collective kind / postcondition
_LB_FACTOR = {
    "allreduce": lambda n: 2.0 * (n - 1) / n,
    "all_gather": lambda n: (n - 1) / n,
    "reduce_scatter": lambda n: (n - 1) / n,
    "all_to_all": lambda n: (n - 1) / n,
    "reduce": lambda n: (n - 1) / n,
}


def bandwidth_lower_bound(kind: str, size_bytes: float, n: int) -> float:
    """Minimum bytes through the busiest rank's NIC, by kind."""
    try:
        factor = _LB_FACTOR[kind]
    except KeyError:
        raise ValueError(f"no bandwidth lower bound for kind {kind!r}; "
                         f"known kinds: {tuple(_LB_FACTOR)}") from None
    return factor(max(n, 1)) * float(size_bytes)


def _bound_kind(program: Program) -> str:
    """The collective the program *provably* performs.

    The postcondition, not ``op.kind``: bcube registers under
    ``allreduce`` (legacy cost-model parity) but builds only the
    recursive reduce-scatter phase, and the naive sequential ring's
    typed proof stops at a rooted ``reduce`` — comparing either against
    the full-allreduce bound would misreport efficiency > 1 or < the
    algorithm's true ratio.
    """
    post = program.postcondition
    return post if post in _LB_FACTOR else program.op.kind


def analyze_bounds(
    program: Program,
) -> Tuple[List[Finding], Dict[str, object]]:
    n = program.n
    # chunk_factor-invariant: k repetitions at 1/k payload cost the same
    # in the pure-bandwidth model, so measure the base body at full size
    per_round_cost: List[float] = []
    for rnd in program.rounds:
        sent: Dict[int, float] = {}
        recv: Dict[int, float] = {}
        for f in rnd:
            sent[f.src] = sent.get(f.src, 0.0) + f.size
            recv[f.dst] = recv.get(f.dst, 0.0) + f.size
        per_round_cost.append(max(
            max(sent.values(), default=0.0),
            max(recv.values(), default=0.0)))
    static_cost = sum(per_round_cost)
    bound_kind = _bound_kind(program)
    lb = bandwidth_lower_bound(bound_kind, program.op.size_bytes, n)
    if static_cost <= 0.0:
        efficiency = 1.0            # n=1 degenerate: empty program is optimal
    else:
        efficiency = lb / static_cost
    rounds_executed = program.n_rounds
    log2_floor = int(math.ceil(math.log2(n))) if n > 1 else 0

    findings = [finding(
        PASS, "BANDWIDTH_EFFICIENCY", "info",
        f"{program.algorithm}: moves {static_cost:.0f} bytes through the "
        f"busiest rank vs a {lb:.0f}-byte lower bound for "
        f"{bound_kind} — efficiency {efficiency:.3f}; "
        f"{rounds_executed} rounds vs ceil(log2 n) = {log2_floor}",
        efficiency=round(efficiency, 6))]
    stats: Dict[str, object] = {
        "static_cost_bytes": static_cost,
        "bound_kind": bound_kind,
        "lower_bound_bytes": lb,
        "bandwidth_efficiency": round(efficiency, 6),
        "rounds_executed": rounds_executed,
        "log2_round_floor": log2_floor,
    }
    return findings, stats
