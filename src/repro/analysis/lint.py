"""AST-level custom lint: repo conventions generic linters can't see.

Seven rules, each born from a real convention this codebase adopted and
then had to re-fix by hand at least once:

* ``raw-perf-counter`` — ``time.perf_counter`` outside ``repro/obs``.
  PR 7 centralized wall-clock measurement behind ``obs.tracer().timer``
  so capture/replay can virtualize the clock; a raw perf_counter pair
  is invisible to trace capture and silently wrong under replay.
  Scope: ``src/repro`` only (tests and benchmarks may time freely).
* ``warn-stacklevel`` — every ``warnings.warn`` call must pass
  ``stacklevel`` so the warning points at the *caller*, not the
  library line.  Scope: everything scanned.
* ``toplevel-jax-import`` — the planning layers (core, fabric, plan,
  session, faults, obs, analysis, the collective IR, the CLI) must be
  importable without jax; only the jax-native packages (kernels,
  models, parallel, train, optim, serve, data, checkpoint, launch
  specs, the collective executors) may import it at module level.
  Imports guarded by ``try/except ImportError`` or
  ``if TYPE_CHECKING`` don't count.
* ``deprecation-warning-category`` — a ``warnings.warn`` whose message
  mentions deprecation must pass ``DeprecationWarning`` (or
  ``FutureWarning``), otherwise ``-W error::DeprecationWarning`` CI
  runs and downstream filters never see it.
* ``lowered-construction`` — ``Lowered`` / ``LoweredSchedule`` /
  ``PermuteStep`` may only be constructed in
  ``collective/executors.py`` (the one certified lowering path) and
  ``repro.analysis`` (the translation validator and its mutant
  screen).  A schedule constructed anywhere else never went through
  ``equiv`` bisimulation, so a runtime consuming it would execute an
  unproven schedule.  Scope: ``src/repro`` (tests may build fixtures).
* ``direct-schedule-run`` — the workload layers (``train/``,
  ``serve/``) must not call ``run_schedule`` directly: the certified
  schedule reaches a step fused (``repro.kernels.overlap`` /
  ``OverlapGradReducer``) or via ``Session``, which pin the
  certification boundary and keep the overlap accounting (bucket
  records, exposed-comm spans) truthful.  A bare ``run_schedule``
  call bypasses both.  Scope: ``src/repro/train``, ``src/repro/serve``.
* ``module-level-np-random`` — legacy global-state ``np.random.*``
  calls (``seed``, ``rand``, ``normal``...) at module import time make
  results depend on import order; use a seeded
  ``np.random.default_rng`` (or ``RandomState``) inside the code that
  needs it.  Seeded constructors are exempt.  Scope: everything
  scanned.

Waivers: append ``# lint: allow(<rule-name>)`` to the offending line
(or the line directly above).  Waivers are for load-bearing exceptions
— the probe's RTT measurement *is* the clock; the solver's hot-loop
timeout cannot take a tracer import — and each one should say why in a
neighboring comment.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["RULES", "LintFinding", "lint_file", "lint_repo",
           "iter_python_files"]

#: rule name -> one-line description (the registry the CLI prints)
RULES: Dict[str, str] = {
    "raw-perf-counter":
        "time.perf_counter outside repro/obs (use obs.tracer().timer)",
    "warn-stacklevel":
        "warnings.warn without stacklevel=",
    "toplevel-jax-import":
        "unguarded module-level jax import in a planning layer",
    "deprecation-warning-category":
        "deprecation message warned without DeprecationWarning",
    "lowered-construction":
        "Lowered/LoweredSchedule/PermuteStep built outside the "
        "certified lowering path (collective/executors.py + analysis)",
    "module-level-np-random":
        "legacy np.random.* global-state call at module import time",
    "direct-schedule-run":
        "run_schedule called from train/ or serve/ (go through the "
        "overlap layer or Session)",
}

#: src/repro-relative prefixes allowed to import jax at module level
_JAX_NATIVE = (
    "kernels/", "models/", "parallel/", "train/", "optim/", "serve/",
    "data/", "checkpoint/",
    "launch/specs.py", "collective/executors.py",
)

_WAIVER = "# lint: allow("


@dataclasses.dataclass(frozen=True)
class LintFinding:
    """One rule violation at one source location."""

    rule: str
    path: str          # repo-relative
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _waived(lines: Sequence[str], lineno: int, rule: str) -> bool:
    """True when the line (or the one above) carries an allow waiver."""
    token = f"{_WAIVER}{rule})"
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and token in lines[ln - 1]:
            return True
    return False


def _is_jax_import(node: ast.stmt) -> bool:
    if isinstance(node, ast.Import):
        return any(a.name == "jax" or a.name.startswith("jax.")
                   for a in node.names)
    if isinstance(node, ast.ImportFrom):
        mod = node.module or ""
        return node.level == 0 and (mod == "jax" or mod.startswith("jax."))
    return False


def _module_level_jax_imports(tree: ast.Module) -> List[ast.stmt]:
    """Unguarded module-level jax imports (try/except and TYPE_CHECKING
    blocks don't count — those are the sanctioned guards)."""
    out: List[ast.stmt] = []
    for node in tree.body:
        if _is_jax_import(node):
            out.append(node)
        elif isinstance(node, ast.If):
            # "if TYPE_CHECKING:" guards typing-only imports
            t = node.test
            is_tc = (isinstance(t, ast.Name) and t.id == "TYPE_CHECKING") \
                or (isinstance(t, ast.Attribute) and t.attr == "TYPE_CHECKING")
            if not is_tc:
                out.extend(s for s in node.body if _is_jax_import(s))
        # ast.Try at module level is the other guard: don't descend
    return out


def _is_warnings_warn(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr == "warn" and \
            isinstance(f.value, ast.Name) and f.value.id == "warnings":
        return True
    return isinstance(f, ast.Name) and f.id == "warn"


def _string_parts(node: ast.AST) -> Iterable[str]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            yield sub.value


def _warn_category(call: ast.Call) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "category":
            return kw.value
    if len(call.args) >= 2:
        return call.args[1]
    return None


def _category_name(node: Optional[ast.AST]) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _check_warn_calls(tree: ast.Module, rel: str,
                      lines: Sequence[str]) -> List[LintFinding]:
    findings: List[LintFinding] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _is_warnings_warn(node)):
            continue
        if not any(kw.arg == "stacklevel" for kw in node.keywords) and \
                len(node.args) < 3:
            if not _waived(lines, node.lineno, "warn-stacklevel"):
                findings.append(LintFinding(
                    "warn-stacklevel", rel, node.lineno,
                    "warnings.warn without stacklevel= — the warning "
                    "will point at the library, not the caller"))
        msg_mentions_deprecation = node.args and any(
            "deprecat" in s.lower() for s in _string_parts(node.args[0]))
        if msg_mentions_deprecation:
            cat = _category_name(_warn_category(node))
            if cat not in ("DeprecationWarning", "FutureWarning",
                           "PendingDeprecationWarning"):
                if not _waived(lines, node.lineno,
                               "deprecation-warning-category"):
                    findings.append(LintFinding(
                        "deprecation-warning-category", rel, node.lineno,
                        f"deprecation message warned with category "
                        f"{cat or 'UserWarning (default)'} — use "
                        f"DeprecationWarning so -W filters catch it"))
    return findings


def _check_perf_counter(tree: ast.Module, rel: str,
                        lines: Sequence[str]) -> List[LintFinding]:
    findings: List[LintFinding] = []
    for node in ast.walk(tree):
        lineno = None
        if isinstance(node, ast.Attribute) and node.attr == "perf_counter":
            lineno = node.lineno
        elif isinstance(node, ast.ImportFrom) and node.module == "time" and \
                any(a.name == "perf_counter" for a in node.names):
            lineno = node.lineno
        if lineno is not None and \
                not _waived(lines, lineno, "raw-perf-counter"):
            findings.append(LintFinding(
                "raw-perf-counter", rel, lineno,
                "raw time.perf_counter — use obs.tracer().timer() / "
                ".span() so capture/replay can virtualize the clock"))
    return findings


def _check_jax_imports(tree: ast.Module, rel: str,
                       lines: Sequence[str]) -> List[LintFinding]:
    findings: List[LintFinding] = []
    for node in _module_level_jax_imports(tree):
        if not _waived(lines, node.lineno, "toplevel-jax-import"):
            findings.append(LintFinding(
                "toplevel-jax-import", rel, node.lineno,
                "unguarded module-level jax import in a planning layer "
                "— import lazily inside the function, or guard with "
                "try/except ImportError"))
    return findings


#: src/repro-relative prefixes allowed to construct lowering artifacts
_LOWERING_PATH = ("collective/executors.py", "analysis/")

#: the lowering artifact class names the rule guards
_LOWERED_NAMES = ("Lowered", "LoweredSchedule", "PermuteStep")

#: np.random attributes that are seeded constructors, not global state
_NP_RANDOM_SEEDED = ("default_rng", "Generator", "RandomState",
                     "SeedSequence", "PCG64", "Philox", "MT19937",
                     "bit_generator")


def _check_lowered_construction(tree: ast.Module, rel: str,
                                lines: Sequence[str]) -> List[LintFinding]:
    findings: List[LintFinding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name = f.id if isinstance(f, ast.Name) else (
            f.attr if isinstance(f, ast.Attribute) else None)
        if name not in _LOWERED_NAMES:
            continue
        if not _waived(lines, node.lineno, "lowered-construction"):
            findings.append(LintFinding(
                "lowered-construction", rel, node.lineno,
                f"{name} constructed outside the certified lowering "
                f"path — schedules must come from JaxExecutor.lower "
                f"(collective/executors.py) so equiv bisimulation "
                f"covers them"))
    return findings


#: src/repro-relative prefixes barred from calling run_schedule directly
_WORKLOAD_LAYERS = ("train/", "serve/")


def _check_direct_schedule_run(tree: ast.Module, rel: str,
                               lines: Sequence[str]) -> List[LintFinding]:
    findings: List[LintFinding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        name = f.id if isinstance(f, ast.Name) else (
            f.attr if isinstance(f, ast.Attribute) else None)
        if name != "run_schedule":
            continue
        if not _waived(lines, node.lineno, "direct-schedule-run"):
            findings.append(LintFinding(
                "direct-schedule-run", rel, node.lineno,
                "run_schedule called from a workload layer — fuse the "
                "certified schedule via repro.kernels.overlap "
                "(run_overlapped / OverlapGradReducer) or go through "
                "Session, so the certification boundary and overlap "
                "accounting hold"))
    return findings


def _module_level_calls(tree: ast.Module) -> List[ast.Call]:
    """Call nodes executed at import time: module and class bodies,
    but nothing inside a function/lambda/comprehension-lambda."""
    out: List[ast.Call] = []

    def visit(stmts) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(stmt, ast.ClassDef):
                visit(stmt.body)
                continue
            for node in ast.walk(stmt):
                if isinstance(node, ast.Lambda):
                    continue
                if isinstance(node, ast.Call):
                    out.append(node)

    visit(tree.body)
    return out


def _check_np_random(tree: ast.Module, rel: str,
                     lines: Sequence[str]) -> List[LintFinding]:
    findings: List[LintFinding] = []
    # lambdas defer execution: prune calls inside them
    deferred = {id(c) for stmt in ast.walk(tree)
                if isinstance(stmt, ast.Lambda)
                for c in ast.walk(stmt) if isinstance(c, ast.Call)}
    for node in _module_level_calls(tree):
        if id(node) in deferred:
            continue
        f = node.func
        if not (isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Attribute)
                and f.value.attr == "random"
                and isinstance(f.value.value, ast.Name)
                and f.value.value.id in ("np", "numpy")):
            continue
        if f.attr in _NP_RANDOM_SEEDED:
            continue
        if not _waived(lines, node.lineno, "module-level-np-random"):
            findings.append(LintFinding(
                "module-level-np-random", rel, node.lineno,
                f"np.random.{f.attr} at module import time mutates "
                f"global RNG state — use a seeded "
                f"np.random.default_rng inside the consuming code"))
    return findings


def lint_file(path: str, root: str) -> List[LintFinding]:
    """All rule violations in one file; ``root`` anchors scoping."""
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    with open(path, encoding="utf-8") as fh:
        src = fh.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [LintFinding("syntax", rel, e.lineno or 0,
                            f"file does not parse: {e.msg}")]
    lines = src.splitlines()

    findings = _check_warn_calls(tree, rel, lines)
    in_repro = rel.startswith("src/repro/")
    if in_repro and not rel.startswith("src/repro/obs/"):
        findings.extend(_check_perf_counter(tree, rel, lines))
    if in_repro:
        sub = rel[len("src/repro/"):]
        if not any(sub.startswith(p) for p in _JAX_NATIVE):
            findings.extend(_check_jax_imports(tree, rel, lines))
        if not any(sub.startswith(p) for p in _LOWERING_PATH):
            findings.extend(_check_lowered_construction(tree, rel, lines))
        if any(sub.startswith(p) for p in _WORKLOAD_LAYERS):
            findings.extend(_check_direct_schedule_run(tree, rel, lines))
    findings.extend(_check_np_random(tree, rel, lines))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def iter_python_files(root: str,
                      subdirs: Sequence[str] = ("src", "tests",
                                                "benchmarks", "examples"),
                      ) -> List[str]:
    out: List[str] = []
    for sub in subdirs:
        base = os.path.join(root, sub)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            out.extend(os.path.join(dirpath, f)
                       for f in filenames if f.endswith(".py"))
    return sorted(out)


def lint_repo(root: str,
              paths: Optional[Sequence[str]] = None,
              ) -> Tuple[List[LintFinding], int]:
    """Lint the repo (or explicit ``paths``); returns (findings, n_files)."""
    files = list(paths) if paths else iter_python_files(root)
    findings: List[LintFinding] = []
    for f in files:
        findings.extend(lint_file(f, root))
    return findings, len(files)
