"""Liveness / redundancy analysis: dead chunks and duplicate transfers.

Extends ``ir.validate``'s abstract chunk interpretation with
*provenance*: every per-(rank, chunk) state entry carries, besides its
contributor set, the set of instructions that transitively built it.
Slicing backwards from the entries the declared postcondition reads
yields the live set; everything else moved bytes that never reach the
result:

* **DEAD_TRANSFER** (warning) — an instruction outside the backward
  slice of the postcondition: it delivered data no required entry ever
  incorporates.  A duplicated or vestigial round shows up here.
* **DUPLICATE_DELIVERY** (warning) — two flows deliver the same chunk
  with identical contributor sets to the same rank in one round.
* **DUPLICATE_ROUND** (warning) — two *adjacent* rounds are identical;
  no correct builder emits the same barrier twice in a row (the naive
  sequential ring's two laps are identical as a sequence but never
  adjacent).
* **NO_EFFECT_TRANSFER** (info) — a reduce that adds no new
  contributors or a copy that rewrites an identical entry.  Info, not
  warning: the naive sequential ring's second lap re-walks its hop
  sequence by design (see ``_ring_sequential_allreduce``), so a
  no-effect transfer can still be load-bearing for the *typed* proof.
"""

from __future__ import annotations

from typing import Dict, FrozenSet, List, Set, Tuple

from repro.collective.ir import Program, _initial_state

from .report import Finding, finding

__all__ = ["analyze_liveness"]

PASS = "liveness"

#: state entry: (contributor ranks, provenance instruction ids)
Entry = Tuple[FrozenSet[int], FrozenSet[int]]


def _required_entries(program: Program,
                      state: Dict[int, Dict[int, Entry]]) -> Set[Tuple[int, int]]:
    """(rank, chunk) entries the declared postcondition reads."""
    n = program.n
    post = program.postcondition
    if post == "allreduce" or post == "all_gather":
        return {(r, c) for r in range(n) for c in range(program.n_chunks)}
    if post == "reduce_scatter":
        return {(r, r) for r in range(n)}
    if post == "all_to_all":
        return {(d, s * n + d) for s in range(n) for d in range(n)}
    if post == "reduce":
        # rooted reduce: the witness is any rank holding every chunk
        # fully reduced — slice from the first such rank
        full = frozenset(range(n))
        for r in range(n):
            if all(state[r].get(c, (frozenset(), None))[0] == full
                   for c in range(program.n_chunks)):
                return {(r, c) for c in range(program.n_chunks)}
        # invalid program (validate flags it); keep every entry live so
        # liveness does not pile misleading findings on top
        return {(r, c) for r in range(n) for c in state[r]}
    # "none": no spec to slice against
    return {(r, c) for r in range(n) for c in state[r]}


def analyze_liveness(
    program: Program,
) -> Tuple[List[Finding], Dict[str, object]]:
    findings: List[Finding] = []
    n = program.n
    # contributor sets start as ir.validate's initial state; provenance
    # starts empty (initial placement has no producing instruction)
    state: Dict[int, Dict[int, Entry]] = {
        r: {c: (contribs, frozenset()) for c, contribs in chunks.items()}
        for r, chunks in _initial_state(program).items()
    }

    instr_id = 0
    n_no_effect = 0
    all_ids: Set[int] = set()
    for r_i, rnd in enumerate(program.rounds):
        if r_i + 1 < len(program.rounds) and rnd == program.rounds[r_i + 1]:
            findings.append(finding(
                PASS, "DUPLICATE_ROUND", "warning",
                f"rounds {r_i} and {r_i + 1} are identical — the same "
                f"barrier executed twice in a row moves "
                f"{sum(f.size for f in rnd):.0f} redundant bytes",
                round=r_i))
        # barrier: collect deliveries against round-entry state
        updates: List[Tuple[str, int, int, Entry]] = []
        arrivals: Dict[Tuple[int, int], List[Tuple[FrozenSet[int], int]]] = {}
        for f in rnd:
            all_ids.add(instr_id)
            for c in f.chunks:
                entry = state[f.src].get(c)
                if entry is None:
                    # unheld send: deps/validate own this error; skip so
                    # liveness keeps analyzing the rest of the program
                    continue
                contribs, prov = entry
                updates.append((f.op, f.dst, c,
                                (contribs, prov | {instr_id})))
                arrivals.setdefault((f.dst, c), []).append(
                    (contribs, instr_id))
            instr_id += 1
        for (dst, c), deliveries in arrivals.items():
            if len(deliveries) > 1:
                seen: Dict[FrozenSet[int], int] = {}
                for contribs, i in deliveries:
                    if contribs in seen:
                        findings.append(finding(
                            PASS, "DUPLICATE_DELIVERY", "warning",
                            f"round {r_i}: chunk {c} delivered twice to "
                            f"rank {dst} with identical contributors "
                            f"(instrs {seen[contribs]} and {i})",
                            round=r_i, dst=dst, chunk=c))
                    else:
                        seen[contribs] = i
        for fop, dst, c, (contribs, prov) in updates:
            old = state[dst].get(c)
            if fop == "reduce":
                if old is not None and contribs <= old[0]:
                    n_no_effect += 1
                    findings.append(finding(
                        PASS, "NO_EFFECT_TRANSFER", "info",
                        f"round {r_i}: reduce into rank {dst} chunk {c} "
                        f"adds no new contributors", round=r_i))
                merged = old if old is not None else (frozenset(), frozenset())
                state[dst][c] = (merged[0] | contribs, merged[1] | prov)
            else:
                if old is not None and old[0] == contribs:
                    n_no_effect += 1
                    findings.append(finding(
                        PASS, "NO_EFFECT_TRANSFER", "info",
                        f"round {r_i}: copy to rank {dst} chunk {c} "
                        f"rewrites an identical entry", round=r_i))
                state[dst][c] = (contribs, prov)

    required = _required_entries(program, state)
    live: Set[int] = set()
    for (r, c) in required:
        entry = state[r].get(c)
        if entry is not None:
            live |= entry[1]
    dead = sorted(all_ids - live)
    if dead:
        findings.append(finding(
            PASS, "DEAD_TRANSFER", "warning",
            f"{len(dead)} instruction(s) outside the backward slice of "
            f"the {program.postcondition!r} postcondition (first ids: "
            f"{dead[:6]}) — transferred bytes never reach the result",
            count=len(dead), instr_ids=dead[:16]))
    stats: Dict[str, object] = {
        "n_live": len(live),
        "n_dead": len(dead),
        "n_no_effect": n_no_effect,
        "n_required_entries": len(required),
    }
    return findings, stats
