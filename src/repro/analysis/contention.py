"""Static contention analysis: per-round link-load histograms.

Three fidelity levels, picked by what the caller can supply:

* a :class:`repro.fabric.Fabric` — exact: every flow's bytes are charged
  to the directed link ids on its path, a round's static bound is the
  most-loaded link's ``bytes / capacity`` (a true lower bound on the
  simulator's max-min fair round time), and links whose load is a
  multiple of the largest single flow crossing them are flagged
  oversubscribed;
* a :class:`repro.fabric.HierarchyModel` — structural: each inferred
  block at each tier owns one logical uplink, flows crossing the block
  boundary load it, and the report shows per-tier crossing histograms
  plus the worst block imbalance (no capacities, so no time bound);
* bare ``(lat, bw)`` probe matrices — pairwise only: the per-round
  bound reuses :func:`repro.fabric.costs.combine_cost` per flow (the one
  shared c_{i,j}(S) formula) with per-rank NIC serialization, matching
  what a live fleet can know without path visibility.

The congestion report this pass assembles is exactly what the
simulator would tell you after running the program — obtained without
running it, which is the point: the plan compiler can surface "this
candidate hammers one uplink" before spending oracle time on it.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.collective.ir import Program
from repro.fabric import Fabric, HierarchyModel
from repro.fabric.costs import combine_cost

from .report import Finding, finding

__all__ = ["analyze_contention", "link_loads"]

PASS = "contention"


def link_loads(program: Program,
               fabric: Fabric) -> List[Dict[int, Tuple[float, int]]]:
    """Per base round: ``{directed link id: (bytes, n_flows)}``.

    Node-space flows of ONE pipeline piece; with ``chunk_factor`` k the
    body repeats k times, so totals scale back to full payload.
    """
    out: List[Dict[int, Tuple[float, int]]] = []
    for rnd in program.piece_flows():
        loads: Dict[int, Tuple[float, int]] = {}
        for f in rnd:
            if f.src == f.dst:
                continue
            for l in fabric.paths[f.src][f.dst]:
                b, k = loads.get(l, (0.0, 0))
                loads[l] = (b + f.size, k + 1)
        out.append(loads)
    return out


def _fabric_contention(program: Program, fabric: Fabric,
                       oversub_threshold: float):
    findings: List[Finding] = []
    per_round = link_loads(program, fabric)
    piece = program.piece_flows()
    k = program.chunk_factor
    total_load: Dict[int, float] = {}
    rounds_summary: List[Dict[str, object]] = []
    total_bound = 0.0
    for r_i, loads in enumerate(per_round):
        if not loads:
            continue
        bound, bottleneck, worst_share = 0.0, None, 0.0
        for l, (bytes_l, n_flows) in loads.items():
            total_load[l] = total_load.get(l, 0.0) + bytes_l * k
            t = bytes_l / max(float(fabric.link_bw[l]), 1.0)
            if t > bound:
                bound, bottleneck = t, l
            if n_flows > 1:
                # serialization factor: how many max-size flows deep
                # the link's queue is (2.0 = pure 2x oversubscription)
                share = bytes_l / max(
                    max(f.size for f in piece[r_i]
                        if l in fabric.paths[f.src][f.dst]), 1e-30)
                worst_share = max(worst_share, share)
                if share >= oversub_threshold:
                    findings.append(finding(
                        PASS, "OVERSUBSCRIBED_LINK", "info",
                        f"round {r_i}: link {l} carries {n_flows} flows "
                        f"({bytes_l:.0f} bytes, {share:.1f}x the largest "
                        f"single flow) — serialization dominates the round",
                        round=r_i, link=l, n_flows=n_flows,
                        share=round(share, 2)))
        total_bound += bound
        rounds_summary.append({
            "round": r_i, "bottleneck_link": bottleneck,
            "bound_s": bound, "max_share": round(worst_share, 2),
            "links_used": len(loads),
        })
    bottleneck_link = None
    if total_load:
        bottleneck_link = max(
            total_load,
            key=lambda l: total_load[l] / max(float(fabric.link_bw[l]), 1.0))
    stats: Dict[str, object] = {
        "mode": "fabric",
        "static_bound_s": total_bound * k,
        "bottleneck_link": bottleneck_link,
        "bottleneck_bytes": total_load.get(bottleneck_link, 0.0),
        "n_links_used": len(total_load),
        "rounds": rounds_summary,
        "link_histogram": {
            str(l): total_load[l]
            for l in sorted(total_load, key=total_load.get, reverse=True)[:16]
        },
    }
    return findings, stats


def _hierarchy_contention(program: Program, hierarchy: HierarchyModel,
                          oversub_threshold: float):
    findings: List[Finding] = []
    # node ids in the program are rank placements over op.group; the
    # hierarchy indexes global nodes, so restrict it to the group
    group = sorted(program.op.group)
    sub = hierarchy.restrict(group) if hierarchy.n != len(group) or \
        list(range(hierarchy.n)) != group else hierarchy
    pos = {node: i for i, node in enumerate(group)}
    tiers: List[Dict[str, object]] = []
    worst_imbalance = 0.0
    for t in range(sub.n_tiers):
        labels = sub.labels(t)
        uplink: Dict[int, float] = {}
        crossings = 0
        for rnd in program.piece_flows():
            for f in rnd:
                a, b = labels[pos[f.src]], labels[pos[f.dst]]
                if a != b:
                    crossings += 1
                    uplink[int(a)] = uplink.get(int(a), 0.0) + f.size
                    uplink[int(b)] = uplink.get(int(b), 0.0) + f.size
        if not uplink:
            tiers.append({"tier": t, "crossings": 0})
            continue
        loads = np.asarray(list(uplink.values()))
        imbalance = float(loads.max() / max(loads.mean(), 1e-30))
        worst_imbalance = max(worst_imbalance, imbalance)
        tiers.append({
            "tier": t, "crossings": crossings,
            "blocks_loaded": len(uplink),
            "max_uplink_bytes": float(loads.max()) * program.chunk_factor,
            "mean_uplink_bytes": float(loads.mean()) * program.chunk_factor,
            "imbalance": round(imbalance, 2),
        })
        if imbalance >= oversub_threshold:
            findings.append(finding(
                PASS, "UPLINK_IMBALANCE", "info",
                f"tier {t}: the busiest block uplink carries "
                f"{imbalance:.1f}x the mean ({loads.max():.0f} bytes) — "
                f"the rank order concentrates cross-block traffic",
                tier=t, imbalance=round(imbalance, 2)))
    stats: Dict[str, object] = {
        "mode": "hierarchy",
        "tiers": tiers,
        "worst_imbalance": round(worst_imbalance, 2),
    }
    return findings, stats


def _pairwise_contention(program: Program, lat: np.ndarray,
                         bw: Optional[np.ndarray]):
    # the shared c_{i,j}(S) formula at unit payload gives per-byte pair
    # costs; each flow is priced at its own size, each round at the max
    # of its slowest flow and its busiest NIC
    c_unit = combine_cost(lat, bw, 1.0)
    base_lat = combine_cost(lat, None, 0.0)
    total = 0.0
    for rnd in program.piece_flows():
        nic: Dict[int, float] = {}
        slowest = 0.0
        for f in rnd:
            if f.src == f.dst:
                continue
            per_byte = c_unit[f.src, f.dst] - base_lat[f.src, f.dst]
            slowest = max(slowest,
                          base_lat[f.src, f.dst] + per_byte * f.size)
            nic[f.src] = nic.get(f.src, 0.0) + per_byte * f.size
        total += max(slowest, max(nic.values(), default=0.0))
    stats: Dict[str, object] = {
        "mode": "pairwise",
        "static_bound_s": total * program.chunk_factor,
    }
    return [], stats


def analyze_contention(
    program: Program,
    fabric: Optional[Fabric] = None,
    hierarchy: Optional[HierarchyModel] = None,
    lat: Optional[np.ndarray] = None,
    bw: Optional[np.ndarray] = None,
    oversub_threshold: float = 2.0,
) -> Tuple[List[Finding], Dict[str, object]]:
    """Congestion report at the best fidelity the inputs allow."""
    if fabric is not None:
        return _fabric_contention(program, fabric, oversub_threshold)
    if hierarchy is not None and not hierarchy.flat:
        return _hierarchy_contention(program, hierarchy, oversub_threshold)
    if lat is not None:
        return _pairwise_contention(program, np.asarray(lat), bw)
    return [], {"mode": "none"}
