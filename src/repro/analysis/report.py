"""Findings and reports: the verdict taxonomy of `repro.analysis`.

Every analysis pass returns :class:`Finding`\\ s at one of three
severities:

* ``error``   — the program is *wrong*: executing it would deadlock,
  lose data, or violate its declared postcondition.  Errors are hard
  gates: the plan compiler refuses to score such a program and
  :func:`repro.analysis.require_valid` raises.
* ``warning`` — the program is suspicious in a way a generated schedule
  should never be (an adjacent duplicated round, an oversubscribed link
  dominating a round) but a human-written algorithm might exhibit on
  purpose.  Warnings fail mutant screening, not compilation.
* ``info``    — measurements, not judgments: bandwidth-efficiency
  ratios, critical-path depth, congestion histograms.

A :class:`Report` aggregates the findings of one verification run plus
per-pass stats; its :meth:`Report.ok` / :meth:`Report.clean` properties
are the two gate levels above.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["SEVERITIES", "Finding", "Report", "VerificationError"]

#: ordered weakest-to-strongest; gates compare by index
SEVERITIES = ("info", "warning", "error")


class VerificationError(ValueError):
    """A program failed static verification (error-level findings).

    Carries the offending :class:`Report` as ``.report`` so callers can
    surface the full finding list, not just the first message.
    """

    def __init__(self, message: str, report: Optional["Report"] = None):
        super().__init__(message)
        self.report = report


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verdict from one analysis pass."""

    pass_name: str                 # registered pass that produced it
    code: str                      # stable machine code, e.g. "DEADLOCK_CYCLE"
    severity: str                  # one of SEVERITIES
    message: str                   # human-readable, names the evidence
    round: Optional[int] = None    # round index the finding anchors to
    detail: Tuple[Tuple[str, object], ...] = ()   # sorted extra evidence

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"expected one of {SEVERITIES}")

    def to_dict(self) -> dict:
        d = {"pass": self.pass_name, "code": self.code,
             "severity": self.severity, "message": self.message}
        if self.round is not None:
            d["round"] = self.round
        if self.detail:
            d["detail"] = dict(self.detail)
        return d


def finding(pass_name: str, code: str, severity: str, message: str,
            round: Optional[int] = None, **detail) -> Finding:
    """Convenience constructor normalizing the detail dict to a tuple."""
    return Finding(pass_name=pass_name, code=code, severity=severity,
                   message=message, round=round,
                   detail=tuple(sorted(detail.items())))


@dataclasses.dataclass
class Report:
    """The verdict of one :func:`repro.analysis.verify_program` run."""

    algorithm: str
    kind: str
    n: int
    program_fingerprint: str
    findings: List[Finding] = dataclasses.field(default_factory=list)
    #: per-pass measurements, e.g. {"deps": {"critical_path_depth": 14}}
    stats: Dict[str, Dict[str, object]] = dataclasses.field(
        default_factory=dict)
    passes_run: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        """No error-level findings: safe to compile, lower, and execute."""
        return not any(f.severity == "error" for f in self.findings)

    @property
    def clean(self) -> bool:
        """No error- or warning-level findings (the mutant-screen gate)."""
        return not any(f.severity in ("error", "warning")
                       for f in self.findings)

    def by_severity(self, severity: str) -> List[Finding]:
        return [f for f in self.findings if f.severity == severity]

    def summary(self) -> str:
        """One line: ``ring n=8 OK (0 err, 0 warn, 2 info)``."""
        counts = {s: len(self.by_severity(s)) for s in SEVERITIES}
        verdict = "OK" if self.ok else "FAIL"
        return (f"{self.algorithm} n={self.n} {verdict} "
                f"({counts['error']} err, {counts['warning']} warn, "
                f"{counts['info']} info)")

    def describe(self) -> str:
        """Multi-line report: summary + every non-info finding + stats."""
        lines = [self.summary()]
        for f in self.findings:
            if f.severity == "info":
                continue
            where = f" round {f.round}" if f.round is not None else ""
            lines.append(f"  [{f.severity}] {f.code}{where}: {f.message}")
        for pname, st in self.stats.items():
            kv = " ".join(f"{k}={v}" for k, v in sorted(st.items())
                          if not isinstance(v, (list, dict)))
            if kv:
                lines.append(f"  {pname}: {kv}")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "algorithm": self.algorithm,
            "kind": self.kind,
            "n": self.n,
            "program_fingerprint": self.program_fingerprint,
            "ok": self.ok,
            "clean": self.clean,
            "findings": [f.to_dict() for f in self.findings],
            "stats": self.stats,
            "passes_run": list(self.passes_run),
        }
