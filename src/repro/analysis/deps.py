"""Deadlock / dependency analysis over a typed collective ``Program``.

Builds the send/recv dependency graph in *data-flow* terms: instruction
B depends on instruction A when B forwards a chunk that A delivered to
B's source rank earlier.  Under the IR's barrier semantics (flows within
a round read round-entry state) every legal dependency points strictly
backwards in round order, so the graph of a correct program is acyclic
by construction — this pass *proves* it by detecting the two ways a
(generated or mutated) program can break the property:

* **intra-round race** — a flow sends a chunk its source only receives
  in the *same* round.  A barrier executor has no defined value to
  send; a rendezvous executor must order the two transfers, and if the
  needs are mutual it deadlocks.
* **missing data** — a flow sends a chunk its source never receives at
  all (also caught by ``ir.validate``'s abstract interpretation; the
  dependency pass reports it with the producing-round evidence so the
  verifier stands alone).

It also reports the critical-path depth (the longest dependency chain,
in instructions), the latency shape every bounds/contention consumer
keys off.
"""

from __future__ import annotations

from typing import Dict, List, Set, Tuple

from repro.collective.ir import Program

from .report import Finding, finding

__all__ = ["analyze_dependencies", "initial_chunks"]

PASS = "deps"


def initial_chunks(program: Program) -> List[Set[int]]:
    """Chunk ids each rank holds before round 0 (id space, not contribs)."""
    n = program.n
    if program.init == "replicated":
        return [set(range(program.n_chunks)) for _ in range(n)]
    if program.init == "sharded":
        return [{r} for r in range(n)]
    if program.init == "addressed":
        return [{r * n + d for d in range(n)} for r in range(n)]
    raise ValueError(f"unknown init {program.init!r}")


def analyze_dependencies(
    program: Program,
) -> Tuple[List[Finding], Dict[str, object]]:
    """Findings + stats; see the module docstring for the contract."""
    findings: List[Finding] = []
    held = initial_chunks(program)
    #: (rank, chunk) -> instr id of the latest delivery in an earlier round
    last_producer: Dict[Tuple[int, int], int] = {}
    #: consumer instr id -> producer instr ids (cross-round data edges)
    edges: Dict[int, List[int]] = {}
    depth: Dict[int, int] = {}
    instr_id = 0
    n_instrs = 0
    max_fan_in = 0

    for r_i, rnd in enumerate(program.rounds):
        if not rnd:
            findings.append(finding(
                PASS, "EMPTY_ROUND", "warning",
                f"round {r_i} contains no flows — dead barrier "
                f"(a dropped instruction or a degenerate builder)",
                round=r_i))
            continue
        # same-round deliveries, for race detection (barrier semantics:
        # these are NOT visible to this round's senders)
        delivered_now: Dict[Tuple[int, int], List[int]] = {}
        ids = list(range(instr_id, instr_id + len(rnd)))
        for i, f in zip(ids, rnd):
            for c in f.chunks:
                delivered_now.setdefault((f.dst, c), []).append(i)
        intra_edges: Dict[int, List[int]] = {}
        for i, f in zip(ids, rnd):
            if f.src == f.dst and program.n > 1:
                findings.append(finding(
                    PASS, "SELF_SEND", "error",
                    f"round {r_i}: rank {f.src} sends to itself "
                    f"(chunks {list(f.chunks)[:4]})", round=r_i,
                    src=f.src))
                continue
            producers: List[int] = []
            for c in f.chunks:
                prod = last_producer.get((f.src, c))
                if prod is not None:
                    producers.append(prod)
                elif c not in held[f.src]:
                    same_round = [j for j in delivered_now.get((f.src, c), ())
                                  if j != i]
                    if same_round:
                        findings.append(finding(
                            PASS, "INTRA_ROUND_RACE", "error",
                            f"round {r_i}: rank {f.src} sends chunk {c} "
                            f"that is only delivered to it within the same "
                            f"round — undefined under barrier semantics, "
                            f"rendezvous-order dependent otherwise",
                            round=r_i, src=f.src, dst=f.dst, chunk=c))
                        intra_edges.setdefault(i, []).extend(same_round)
                    else:
                        findings.append(finding(
                            PASS, "MISSING_DATA", "error",
                            f"round {r_i}: rank {f.src} sends chunk {c} "
                            f"it never held nor received",
                            round=r_i, src=f.src, dst=f.dst, chunk=c))
            if producers:
                edges[i] = producers
                max_fan_in = max(max_fan_in, len(set(producers)))
            # a producer skipped as SELF_SEND has no depth: floor it at 1
            depth[i] = 1 + max((depth.get(p, 1) for p in producers),
                               default=0)
        # mutual intra-round needs are a rendezvous deadlock cycle
        for i, needs in intra_edges.items():
            for j in needs:
                if i in intra_edges.get(j, ()):  # pragma: no branch
                    findings.append(finding(
                        PASS, "DEADLOCK_CYCLE", "error",
                        f"round {r_i}: instructions {min(i, j)} and "
                        f"{max(i, j)} each need the chunk the other "
                        f"delivers in the same round — rendezvous deadlock",
                        round=r_i))
                    break
        # barrier: commit this round's deliveries
        for (dst, c), prods in delivered_now.items():
            held[dst].add(c)
            last_producer[(dst, c)] = max(prods)
        n_instrs += len(rnd)
        instr_id += len(rnd)

    critical_path = max(depth.values(), default=0)
    stats: Dict[str, object] = {
        "n_instrs": n_instrs,
        "n_rounds": program.n_rounds,
        "critical_path_depth": critical_path * program.chunk_factor,
        "max_fan_in": max_fan_in,
        "acyclic": not any(f.code in ("DEADLOCK_CYCLE", "INTRA_ROUND_RACE")
                           for f in findings),
    }
    return findings, stats
