"""The pass driver: run registered analyses, aggregate one Report.

``verify_program`` is the single entry point every consumer uses — the
plan compiler's candidate gate, ``Session.lower``'s pre-flight check,
``fuse_rounds``'s post-condition, the CLI sweep, and the mutant screen
all call it with different pass subsets and context.

The registry is ordered: cheap structural proof first, semantics next,
then the measurements.  A pass that *raises* is itself a verification
failure (PASS_CRASH, error) rather than an analysis escape hatch — a
verifier that silently skips a crashed pass proves nothing.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.collective import ir
from repro.collective.ir import Program

from . import bounds as _bounds
from . import contention as _contention
from . import deps as _deps
from . import equiv as _equiv
from . import liveness as _liveness
from .report import Finding, Report, VerificationError, finding

__all__ = ["PASSES", "PassContext", "verify_program", "require_valid"]


@dataclasses.dataclass
class PassContext:
    """Optional environment a pass may consult; all fields may be None."""

    fabric: Optional[object] = None          # repro.fabric.Fabric
    hierarchy: Optional[object] = None       # repro.fabric.HierarchyModel
    lat: Optional[np.ndarray] = None         # probed latency matrix
    bw: Optional[np.ndarray] = None          # probed bandwidth matrix
    oversub_threshold: float = 2.0

    @property
    def has_topology(self) -> bool:
        return (self.fabric is not None or self.hierarchy is not None
                or self.lat is not None)


def _run_validate(program: Program,
                  ctx: PassContext) -> Tuple[List[Finding], Dict[str, object]]:
    """ir.validate as a pass: invariant violations become error findings."""
    try:
        ir.validate(program)
    except ir.ProgramInvariantError as e:
        return [finding("validate", "INVARIANT_VIOLATION", "error", str(e))], {}
    return [], {"structural": True, "semantic": True}


def _run_deps(program, ctx):
    return _deps.analyze_dependencies(program)


def _run_liveness(program, ctx):
    return _liveness.analyze_liveness(program)


def _run_equiv(program, ctx):
    return _equiv.analyze_equiv(program)


def _run_bounds(program, ctx):
    return _bounds.analyze_bounds(program)


def _run_contention(program, ctx):
    return _contention.analyze_contention(
        program, fabric=ctx.fabric, hierarchy=ctx.hierarchy,
        lat=ctx.lat, bw=ctx.bw, oversub_threshold=ctx.oversub_threshold)


#: ordered registry: name -> pass(program, ctx) -> (findings, stats)
PASSES: Dict[str, Callable[[Program, PassContext],
                           Tuple[List[Finding], Dict[str, object]]]] = {
    "validate": _run_validate,
    "deps": _run_deps,
    "liveness": _run_liveness,
    "equiv": _run_equiv,
    "bounds": _run_bounds,
    "contention": _run_contention,
}

#: passes that prove correctness (the gate set); measurements excluded.
#: ``equiv`` makes every compile gate a translation-validation gate:
#: the program is lowered and the schedule bisimulated as part of
#: passing verification.
GATE_PASSES = ("validate", "deps", "liveness", "equiv")


def verify_program(
    program: Program,
    passes: Optional[Sequence[str]] = None,
    fabric=None,
    hierarchy=None,
    lat=None,
    bw=None,
    oversub_threshold: float = 2.0,
) -> Report:
    """Run ``passes`` (default: all registered) and aggregate a Report.

    The contention pass degrades gracefully to a no-op without topology
    context, so running "all" passes is always safe.
    """
    ctx = PassContext(fabric=fabric, hierarchy=hierarchy, lat=lat, bw=bw,
                      oversub_threshold=oversub_threshold)
    names = list(passes) if passes is not None else list(PASSES)
    unknown = [p for p in names if p not in PASSES]
    if unknown:
        raise ValueError(f"unknown analysis pass(es) {unknown}; "
                         f"registered: {tuple(PASSES)}")
    report = Report(algorithm=program.algorithm, kind=program.op.kind,
                    n=program.n, program_fingerprint=program.fingerprint())
    for name in names:
        try:
            findings, stats = PASSES[name](program, ctx)
        except Exception as e:  # noqa: BLE001 — a crashed pass is a verdict
            findings, stats = [finding(
                name, "PASS_CRASH", "error",
                f"analysis pass {name!r} crashed: "
                f"{type(e).__name__}: {e}")], {}
        report.findings.extend(findings)
        if stats:
            report.stats[name] = stats
        report.passes_run.append(name)
    return report


def require_valid(program: Program, **context) -> Report:
    """Verify and raise :class:`VerificationError` on any error finding.

    The hard-gate form used by the plan compiler and ``Session.lower``;
    returns the (possibly warning-bearing) report when the program is
    sound so callers can still surface the measurements.
    """
    report = verify_program(program, **context)
    if not report.ok:
        errors = report.by_severity("error")
        raise VerificationError(
            f"program {program.algorithm} (n={program.n}, "
            f"kind={program.op.kind}) failed static verification with "
            f"{len(errors)} error(s): {errors[0].code} — {errors[0].message}",
            report=report)
    return report
