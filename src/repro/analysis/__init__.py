"""repro.analysis — static verification of collective Programs.

A pass framework over the typed IR (:mod:`repro.collective.ir`) that
proves schedule properties *without* executing or simulating them:

* :mod:`~repro.analysis.deps` — send/recv dependency graph per round;
  proves acyclicity (no rendezvous deadlock, no intra-round races, no
  self-sends) and reports critical-path depth;
* :mod:`~repro.analysis.liveness` — provenance-carrying abstract chunk
  interpretation; detects dead transfers, duplicate deliveries, and
  duplicated rounds by slicing backwards from the postcondition;
* :mod:`~repro.analysis.equiv` — translation validation: symbolically
  executes the ``LoweredSchedule`` an executor produced and proves
  chunk-for-chunk bisimulation against the source Program, so every
  schedule handed to real devices carries a certificate;
* :mod:`~repro.analysis.bounds` — statically derived cost vs the
  per-kind bandwidth lower bound (bandwidth-efficiency ratio);
* :mod:`~repro.analysis.contention` — per-round link-load histograms
  over a Fabric / HierarchyModel / probe matrices; the congestion
  report without running the simulator.

:func:`verify_program` drives the registered passes and aggregates a
:class:`Report`; :func:`require_valid` is the hard-gate form the plan
compiler and ``Session.lower`` call.  :mod:`~repro.analysis.mutate`
screens the verifier itself against seeded program mutations, and
:mod:`~repro.analysis.lint` is the repo's AST-level custom lint gate
(``repro analyze --lint``).

See DESIGN.md §11 for the pass architecture and the verdict taxonomy.
"""

from .bounds import analyze_bounds, bandwidth_lower_bound  # noqa: F401
from .contention import analyze_contention, link_loads  # noqa: F401
from .deps import analyze_dependencies  # noqa: F401
from .equiv import (  # noqa: F401
    bisimulate,
    certify_stages,
    require_certified,
    symbolic_execute,
)
from .liveness import analyze_liveness  # noqa: F401
from .mutate import (  # noqa: F401
    LOWERING_MUTATIONS,
    MUTATIONS,
    kill_rate,
    lowering_kill_rate,
    lowering_mutants,
    mutants,
)
from .report import (  # noqa: F401
    SEVERITIES,
    Finding,
    Report,
    VerificationError,
)
from .verify import (  # noqa: F401
    GATE_PASSES,
    PASSES,
    PassContext,
    require_valid,
    verify_program,
)

__all__ = [
    "SEVERITIES",
    "Finding",
    "Report",
    "VerificationError",
    "PASSES",
    "GATE_PASSES",
    "PassContext",
    "verify_program",
    "require_valid",
    "analyze_dependencies",
    "analyze_liveness",
    "analyze_bounds",
    "analyze_contention",
    "link_loads",
    "bandwidth_lower_bound",
    "MUTATIONS",
    "mutants",
    "kill_rate",
    "LOWERING_MUTATIONS",
    "lowering_mutants",
    "lowering_kill_rate",
    "bisimulate",
    "symbolic_execute",
    "certify_stages",
    "require_certified",
]
